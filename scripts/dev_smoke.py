"""Dev harness: reduced-config forward/decode for every arch (not a test)."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import transformer

names = sys.argv[1:] or list(registry.ARCHS)
for name in names:
    cfg = registry.smoke(name)
    key = jax.random.key(0)
    params = transformer.init_params(key, cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    B, T = 2, 32
    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab_size)}
    if cfg.vision_prefix:
        batch["vision_embeds"] = jnp.ones((B, cfg.vision_prefix, cfg.d_model), cfg.jdtype) * 0.01
    if cfg.encoder_layers:
        batch["enc_embeds"] = jnp.ones((B, cfg.encoder_len, cfg.d_model), cfg.jdtype) * 0.01
    loss = transformer.loss_fn(params, cfg, batch)
    # prefill + decode
    logits, aux, cache = transformer.forward(params, cfg, batch, mode="prefill", max_len=T + 8)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    extras = {}
    if cfg.vision_prefix:
        p0 = T + cfg.vision_prefix
        extras["positions"] = jnp.full((3, B, 1), p0, jnp.int32)
    lg2, cache = transformer.decode_step(params, cfg, tok, cache, jnp.int32(T), extras)
    ok = bool(jnp.isfinite(loss)) and bool(jnp.all(jnp.isfinite(lg2)))
    print(f"{name:26s} params={n/1e6:8.2f}M loss={float(loss):8.4f} decode_ok={ok}")
