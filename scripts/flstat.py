"""Summarize a telemetry JSONL stream (repro.telemetry schema).

Reproduces a run's headline numbers — rounds run, final accuracy,
rounds-to-target — from the stream ALONE (no checkpoint, no rerun), plus
per-span wall-clock percentiles and, with --nodes, each node's FedAdp
angle/weight trajectory. `--validate` checks every event against the
versioned schema; `--assert-weight-sums` checks the softmax invariant
(each round's node weights sum to 1) — CI runs both on every stream a
smoke job produces.

Usage:
  python scripts/flstat.py RUN_DIR/telemetry.jsonl
  python scripts/flstat.py BENCH_telemetry.jsonl --target 0.85 \
      --validate --assert-weight-sums --nodes
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.telemetry import report, schema  # noqa: E402
from repro.telemetry.sinks import load_events  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="summarize a repro.telemetry JSONL stream")
    ap.add_argument("path", help="telemetry .jsonl file")
    ap.add_argument("--target", type=float, default=0.85,
                    help="accuracy target for rounds-to-target "
                         "(default: the paper's 0.85)")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check every event; non-zero exit on "
                         "violation")
    ap.add_argument("--assert-weight-sums", action="store_true",
                    help="assert each round's node weights sum to 1 "
                         "(1e-5); non-zero exit on violation")
    ap.add_argument("--nodes", action="store_true",
                    help="per-node trajectory lines")
    args = ap.parse_args(argv)

    events = load_events(args.path)
    try:
        if args.validate:
            counts = schema.validate_events(events)
            print("valid:",
                  " ".join(f"{k}={v}" for k, v in counts.items() if v))
        if args.assert_weight_sums:
            n = report.check_weight_sums(events)
            print(f"weight sums ok ({n} rounds)")
    except ValueError as e:
        print(f"flstat: {e}", file=sys.stderr)
        return 1
    print(report.format_summary(report.summarize(events, args.target),
                                per_node=args.nodes))
    return 0


if __name__ == "__main__":
    sys.exit(main())
