"""Regenerate tests/golden/convergence.json — the pinned Table-I claim.

Runs the fixed-seed 5 IID + 5 one-class synthetic task for fedadp vs
fedavg across EVERY (uplink, downlink) wire pair (including int4 and the
quantized downlinks) and records rounds-to-85%. The committed JSON is the
golden the regression test (tests/test_golden_convergence.py) checks its
claims and re-runs against; regenerate ONLY when an intentional algorithm
change shifts convergence, and eyeball the diff — fedadp must stay <=
fedavg and every wire within 10% of the f32/f32 reference.

The trajectories come from the DEVICE-RNG data pipeline (core.driver:
on-device epoch permutations + client selection, eval_every=1 for exact
round counts) — the stepwise and scanned drivers share it, so one golden
pins both; tests/test_driver.py re-converges a subset through the
scanned path.

Usage:  PYTHONPATH=src python scripts/gen_golden_convergence.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import node_spec, run_fl  # noqa: E402
from repro import transport  # noqa: E402

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "..", "tests",
                           "golden", "convergence.json")

# The fixed-seed task (matches benchmarks/run.py transport_sweep): every
# field here is an INPUT to the runs; the test replays them verbatim.
TASK = {
    "spec": "5iid+5non1",
    "target": 0.85,
    "max_rounds": 60,
    "seed": 0,
    "engine": "flat",
    "group_size": 512,
    "eval_every": 1,
}


def run_matrix():
    entries = {}
    spec = node_spec(5, 5, 1)
    for method in ("fedavg", "fedadp"):
        for uplink in transport.TRANSPORTS:
            for downlink in transport.DOWNLINKS:
                hist, _ = run_fl(
                    method, spec, rounds=TASK["max_rounds"],
                    target=TASK["target"], engine=TASK["engine"],
                    transport=uplink, downlink=downlink,
                    group_size=TASK["group_size"], seed=TASK["seed"],
                    eval_every=TASK["eval_every"],
                )
                key = f"{method}/{uplink}/{downlink}"
                entries[key] = hist.rounds_to_target
                print(f"{key}: {hist.rounds_to_target}", flush=True)
    return entries


def main():
    import jax

    entries = run_matrix()
    payload = {
        "task": TASK,
        "metric": "rounds_to_target_accuracy",
        "generated_with_jax": jax.__version__,
        "entries": entries,
    }
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {os.path.normpath(GOLDEN_PATH)}")


if __name__ == "__main__":
    main()
