"""Regenerate tests/golden/convergence.json — the pinned Table-I claim.

Runs the fixed-seed 5 IID + 5 one-class synthetic task for fedadp vs
fedavg across EVERY (uplink, downlink) wire pair (including int4 and the
quantized downlinks) and records rounds-to-85%. The committed JSON is the
golden the regression test (tests/test_golden_convergence.py) checks its
claims and re-runs against; regenerate ONLY when an intentional algorithm
change shifts convergence, and eyeball the diff — fedadp must stay <=
fedavg and every wire within 10% of the f32/f32 reference.

The trajectories come from the DEVICE-RNG data pipeline (core.driver:
on-device epoch permutations + client selection, eval_every=1 for exact
round counts) — the stepwise and scanned drivers share it, so one golden
pins both; tests/test_driver.py re-converges a subset through the
scanned path.

Usage:  PYTHONPATH=src python scripts/gen_golden_convergence.py
        PYTHONPATH=src python scripts/gen_golden_convergence.py --only-delta

`--only-delta` recomputes just the subset-selection delta-downlink
section and merges it into the committed JSON, leaving the full-
participation `entries`/`buffered` sections byte-identical.
"""
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import repro  # noqa: E402
from benchmarks.common import node_spec, run_fl  # noqa: E402
from repro import transport  # noqa: E402

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "..", "tests",
                           "golden", "convergence.json")

# The fixed-seed task (matches benchmarks/run.py transport_sweep): every
# field here is an INPUT to the runs; the test replays them verbatim.
TASK = {
    "spec": "5iid+5non1",
    "target": 0.85,
    "max_rounds": 60,
    "seed": 0,
    "engine": "flat",
    "group_size": 512,
    "eval_every": 1,
}

# The buffered-async sibling claim: same task, aggregation="buffered",
# under a FIXED straggler/dropout arrival schedule (deterministic, so the
# golden is exact): two reports delayed one tick, one lost in transit,
# flush at buffer_m=8 of 10. Acceptance: buffered fedadp stays within
# 1.1x of the sync golden's rounds on both the uncompressed and the
# fully-compressed wire. "rounds" here count server TICKS.
TASK_BUFFERED = {
    **TASK,
    "aggregation": "buffered",
    "buffer_m": 8,
    "staleness_beta": 0.3,
    "schedule": {
        "ticks": 8,          # rows in the (T, K) schedule; tail reuses row T-1
        "num_clients": 10,
        "delay": 1,          # straggler delay, in server ticks
        "stragglers": [[0, 3], [2, 7]],  # (tick, client) pairs arriving late
        "drops": [[1, 5]],               # (tick, client) reports lost
    },
}

# buffered wires: the reference and the fully-compressed pair
BUFFERED_WIRES = [("f32", "f32"), ("int4", "int8")]

# The delta-downlink sibling claim: same task under 5-of-10 SUBSET
# selection (clients_per_round=5) — the regime where the per-client
# broadcast state (RoundState.bcast: delta ring + last-pulled versions
# + catch-up resync) actually carries state between rounds. Each method
# gets an f32/f32 reference under the same subset selection plus every
# delta wire pair; acceptance mirrors the sync table (fedadp <= fedavg,
# per-wire ratio <= 1.1 vs the same-method reference).
TASK_DELTA = {
    **TASK,
    "max_rounds": 120,
    "clients_per_round": 5,
    "downlink_ring": 8,
}

# delta wires: downlink_delta=True pairs (downlink never accepts int4)
DELTA_WIRES = [("f32", "bf16"), ("f32", "int8"), ("int4", "int8")]


def buffered_arrival_fn(task=TASK_BUFFERED):
    """The fixed schedule of TASK_BUFFERED as an arrival_fn (the test
    rebuilds the same function from the committed JSON)."""
    s = task["schedule"]
    delays = np.zeros((s["ticks"], s["num_clients"]), np.int32)
    drops = np.zeros((s["ticks"], s["num_clients"]), bool)
    for t, k in s["stragglers"]:
        delays[t, k] = s["delay"]
    for t, k in s["drops"]:
        drops[t, k] = True
    return repro.fixed_arrival_schedule(delays, drops)


def run_matrix():
    entries = {}
    spec = node_spec(5, 5, 1)
    for method in ("fedavg", "fedadp"):
        for uplink in transport.TRANSPORTS:
            for downlink in transport.DOWNLINKS:
                hist, _ = run_fl(
                    method, spec, rounds=TASK["max_rounds"],
                    target=TASK["target"], engine=TASK["engine"],
                    transport=uplink, downlink=downlink,
                    group_size=TASK["group_size"], seed=TASK["seed"],
                    eval_every=TASK["eval_every"],
                )
                key = f"{method}/{uplink}/{downlink}"
                entries[key] = hist.rounds_to_target
                print(f"{key}: {hist.rounds_to_target}", flush=True)
    return entries


def run_buffered():
    entries = {}
    spec = node_spec(5, 5, 1)
    t = TASK_BUFFERED
    for uplink, downlink in BUFFERED_WIRES:
        hist, _ = run_fl(
            "fedadp", spec, rounds=t["max_rounds"], target=t["target"],
            engine=t["engine"], transport=uplink, downlink=downlink,
            group_size=t["group_size"], seed=t["seed"],
            eval_every=t["eval_every"], aggregation="buffered",
            buffer_m=t["buffer_m"], staleness_beta=t["staleness_beta"],
            arrival_fn=buffered_arrival_fn(t),
        )
        key = f"fedadp/{uplink}/{downlink}"
        entries[key] = hist.rounds_to_target
        print(f"buffered {key}: {hist.rounds_to_target}", flush=True)
    return entries


def run_delta():
    entries = {}
    spec = node_spec(5, 5, 1)
    t = TASK_DELTA
    for method in ("fedavg", "fedadp"):
        # same-method reference: plain f32 broadcast, same subset selection
        wires = [("f32", "f32", False)] + [(u, d, True) for u, d in DELTA_WIRES]
        for uplink, downlink, delta in wires:
            hist, _ = run_fl(
                method, spec, rounds=t["max_rounds"], target=t["target"],
                engine=t["engine"], transport=uplink, downlink=downlink,
                downlink_delta=delta, downlink_ring=t["downlink_ring"],
                group_size=t["group_size"], seed=t["seed"],
                eval_every=t["eval_every"],
                clients_per_round=t["clients_per_round"],
            )
            key = f"{method}/{uplink}/{downlink}"
            entries[key] = hist.rounds_to_target
            print(f"delta {key}: {hist.rounds_to_target}", flush=True)
    return entries


def main():
    import jax

    only_delta = "--only-delta" in sys.argv[1:]
    if only_delta:
        # Recompute ONLY the subset-selection delta section; every other
        # key of the committed golden (entries, buffered, task, ...) is
        # carried over verbatim so its pinned values cannot drift.
        with open(GOLDEN_PATH) as f:
            payload = json.load(f)
    else:
        payload = {
            "task": TASK,
            "metric": "rounds_to_target_accuracy",
            "generated_with_jax": jax.__version__,
            "entries": run_matrix(),
            "buffered": {
                "task": TASK_BUFFERED,
                "entries": run_buffered(),
            },
        }
    payload["delta"] = {
        "task": TASK_DELTA,
        "wires": [list(w) for w in DELTA_WIRES],
        "entries": run_delta(),
    }
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {os.path.normpath(GOLDEN_PATH)}")


if __name__ == "__main__":
    main()
