"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import treemath
from repro.kernels import grad_dot, ops, ref, round_stats, weighted_agg

SHAPES = [(7,), (128,), (65536,), (1000, 333), (3, 17, 129)]
DTYPES = [jnp.float32, jnp.bfloat16]
# padding edges around the 128*128 block: one short, exact, one over, ragged
NS = [100, 16383, 16384, 16385, 70001]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_grad_dot_stats(shape, dtype):
    a = jax.random.normal(jax.random.key(0), shape, dtype)
    b = jax.random.normal(jax.random.key(1), shape, dtype)
    got = grad_dot.grad_dot_stats(a, b)
    want = ref.grad_dot_stats(a, b)
    rtol = 1e-3 if dtype == jnp.float32 else 2e-2
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=rtol)


@pytest.mark.parametrize("k", [1, 4, 32])
@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_weighted_agg(k, n, dtype):
    x = jax.random.normal(jax.random.key(0), (k, n), dtype)
    w = jax.random.uniform(jax.random.key(1), (k,), jnp.float32)
    got = weighted_agg.weighted_agg(w, x, min_kernel_elems=0)
    want = ref.weighted_agg(w, x)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=1e-2,
    )


@pytest.mark.parametrize("k", [1, 8, 32])
@pytest.mark.parametrize("n", [128, 16385, 50000])
@pytest.mark.parametrize("dtype", DTYPES)
def test_batched_dot(k, n, dtype):
    x = jax.random.normal(jax.random.key(0), (k, n), dtype)
    g = jax.random.normal(jax.random.key(1), (n,), dtype)
    rtol = 1e-3 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(weighted_agg.batched_dot(x, g, min_kernel_elems=0)),
        np.asarray(ref.batched_dot(x, g)), rtol=rtol, atol=1e-2,
    )


@pytest.mark.parametrize("k", [1, 8, 32])
@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_round_stats(k, n, dtype):
    x = jax.random.normal(jax.random.key(0), (k, n), dtype)
    g = jax.random.normal(jax.random.key(1), (n,), dtype)
    got = round_stats.round_stats(x, g, min_kernel_elems=0)
    want = ref.round_stats(x, g)
    rtol = 1e-3 if dtype == jnp.float32 else 2e-2
    for gg, ww, name in zip(got, want, ("dots", "sqnorms", "sqg")):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(ww), rtol=rtol,
                                   err_msg=name)


@pytest.mark.parametrize("n", [100, 16385])
def test_round_stats_masked(n):
    x = jax.random.normal(jax.random.key(0), (4, n), jnp.float32)
    g = jax.random.normal(jax.random.key(1), (n,), jnp.float32)
    mask = (jax.random.uniform(jax.random.key(2), (n,)) > 0.5).astype(
        jnp.float32)
    got = round_stats.round_stats(x, g, mask, min_kernel_elems=0)
    want = ref.round_stats(x, g, mask)
    for gg, ww, name in zip(got, want, ("dots", "sqnorms", "sqg")):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(ww), rtol=1e-3,
                                   err_msg=name)
    # masked stats == stats over the masked subspace, not a rescale
    full = round_stats.round_stats(x, g, min_kernel_elems=0)
    assert not np.allclose(np.asarray(got[1]), np.asarray(full[1]))


# K values straddling the K_TILE=32 client-chunk boundary: degenerate
# single chunk, one full + one ragged chunk, exact multiples.
CHUNK_KS = [1, 33, 64]


@pytest.mark.parametrize("k", CHUNK_KS)
@pytest.mark.parametrize("n", [100, 16385])
@pytest.mark.parametrize("dtype", DTYPES)
def test_chunked_round_stats(k, n, dtype):
    """Client-axis chunking (the former MAX_K trace-time error is gone):
    ragged K + non-multiple-of-block N padding + bf16 inputs."""
    x = jax.random.normal(jax.random.key(0), (k, n), dtype)
    g = jax.random.normal(jax.random.key(1), (n,), dtype)
    got = round_stats.round_stats(x, g, min_kernel_elems=0)
    want = ref.round_stats(x, g)
    rtol = 1e-3 if dtype == jnp.float32 else 2e-2
    for gg, ww, name in zip(got, want, ("dots", "sqnorms", "sqg")):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(ww), rtol=rtol,
                                   atol=1e-2, err_msg=name)


@pytest.mark.parametrize("k", CHUNK_KS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_chunked_weighted_agg_and_batched_dot(k, dtype):
    n = 16385  # one lane-block plus a ragged tail
    x = jax.random.normal(jax.random.key(0), (k, n), dtype)
    g = jax.random.normal(jax.random.key(1), (n,), dtype)
    w = jax.random.uniform(jax.random.key(2), (k,), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(weighted_agg.weighted_agg(w, x, min_kernel_elems=0),
                   np.float32),
        np.asarray(ref.weighted_agg(w, x), np.float32), rtol=2e-2, atol=1e-2)
    np.testing.assert_allclose(
        np.asarray(weighted_agg.batched_dot(x, g, min_kernel_elems=0)),
        np.asarray(ref.batched_dot(x, g)), rtol=2e-2, atol=1e-1)


@pytest.mark.parametrize("dtype", DTYPES)
def test_chunked_round_stats_masked_across_chunk_boundary(dtype):
    """A segment mask spanning both lane tiles and the K=33 ragged client
    chunk: masked stats must equal the oracle over the masked subspace."""
    k, n = 33, 33000  # > 2 lane blocks; 33 clients -> chunks of 32 + 1
    x = jax.random.normal(jax.random.key(0), (k, n), dtype)
    g = jax.random.normal(jax.random.key(1), (n,), dtype)
    # contiguous masked-out segment straddling the first block boundary,
    # as segment_mask produces for a dropped leaf
    mask = jnp.ones((n,), jnp.float32).at[16000:17000].set(0.0)
    got = round_stats.round_stats(x, g, mask, min_kernel_elems=0)
    want = ref.round_stats(x, g, mask)
    rtol = 1e-3 if dtype == jnp.float32 else 2e-2
    for gg, ww, name in zip(got, want, ("dots", "sqnorms", "sqg")):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(ww), rtol=rtol,
                                   atol=1e-1, err_msg=name)
    # the mask must actually bite
    full = round_stats.round_stats(x, g, min_kernel_elems=0)
    assert not np.allclose(np.asarray(got[1]), np.asarray(full[1]))


# ---- int4 packed wire: fused unpack+grouped-dequant kernel parity ----
# (transport-level parity and boundary sweeps live in test_transport.py;
# these pin the KERNEL contract directly on hand-built wire buffers.)


def _int4_wire(key, k, n, gs):
    from repro import transport

    x = jax.random.normal(key, (k, n), jnp.float32)
    q = transport.quantize(x, "int4", group_size=gs)
    return q.values, q.scales


@pytest.mark.parametrize("k", CHUNK_KS)
@pytest.mark.parametrize("gs", [32, 512, 16384])
def test_round_stats_q4_kernel_chunk_and_group_boundaries(k, gs):
    """Ragged client chunks x scale groups that subdivide a kernel tile
    row (gs=32), straddle rows (gs=512), and match the whole chunk."""
    n = 16385  # one byte-tile plus a ragged logical tail (odd N)
    values, scales = _int4_wire(jax.random.key(0), k, n, gs)
    g = jax.random.normal(jax.random.key(1), (n,), jnp.float32)
    got = round_stats.round_stats_q4(values, scales, g, group_size=gs)
    want = ref.round_stats_q4(values, scales, g, group_size=gs)
    for gg, ww, name in zip(got, want, ("dots", "sqnorms", "sqg")):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(ww), rtol=2e-3,
                                   atol=1e-2, err_msg=name)


@pytest.mark.parametrize("k", CHUNK_KS)
@pytest.mark.parametrize("gs", [32, 512, 16384])
def test_weighted_agg_q4_kernel_chunk_and_group_boundaries(k, gs):
    n = 16385
    values, scales = _int4_wire(jax.random.key(2), k, n, gs)
    w = jax.random.uniform(jax.random.key(3), (k,), jnp.float32)
    got = weighted_agg.weighted_agg_q4(w, values, scales, n=n, group_size=gs)
    want = ref.weighted_agg_q4(w, values, scales, n=n, group_size=gs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=1e-3)


def test_q4_kernels_reject_packed_width_mismatch():
    """A packed buffer whose width is not ceil(n/2) is a layout bug, not
    a tolerable input — both kernels must refuse it."""
    values, scales = _int4_wire(jax.random.key(4), 2, 100, 32)
    g = jnp.ones((97,), jnp.float32)  # wrong logical width
    with pytest.raises(AssertionError):
        round_stats.round_stats_q4(values, scales, g, group_size=32)
    with pytest.raises(AssertionError):
        weighted_agg.weighted_agg_q4(jnp.ones((2,)), values, scales, n=97,
                                     group_size=32)


@pytest.mark.parametrize("mk", [0, None])
def test_round_stats_bf16_accumulates_in_f32(mk):
    # 2^14 bf16 ones: naive bf16 accumulation saturates at 256. Pinned on
    # BOTH paths — the Pallas kernel (mk=0) and the small-shape XLA
    # fallback (mk=None: 2*2^14 < SMALL_ELEMS) share the f32 contract.
    n = 1 << 14
    x = jnp.ones((2, n), jnp.bfloat16)
    g = jnp.ones((n,), jnp.bfloat16)
    dots, sqs, sqg = round_stats.round_stats(x, g, min_kernel_elems=mk)
    assert float(sqg) == float(n)
    np.testing.assert_allclose(np.asarray(dots), [n, n])
    np.testing.assert_allclose(np.asarray(sqs), [n, n])


# ---- small-shape XLA fallback (the K=8, d=1024 flat-engine cliff fix) ----


def _has_pallas_call(fn, *args, **kwargs) -> bool:
    text = str(jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args))
    return "pallas_call" in text


@pytest.mark.parametrize("k,n", [(8, 1024), (1, 70001), (4, 16384)])
def test_small_shape_fallback_matches_kernel(k, n):
    """Below SMALL_ELEMS the wrappers dispatch to XLA; both paths must
    agree to kernel-vs-oracle tolerance so the engine A/B cannot fork."""
    assert k * n < weighted_agg.SMALL_ELEMS
    x = jax.random.normal(jax.random.key(0), (k, n), jnp.float32)
    g = jax.random.normal(jax.random.key(1), (n,), jnp.float32)
    w = jax.random.uniform(jax.random.key(2), (k,), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(weighted_agg.weighted_agg(w, x)),
        np.asarray(weighted_agg.weighted_agg(w, x, min_kernel_elems=0)),
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(weighted_agg.batched_dot(x, g)),
        np.asarray(weighted_agg.batched_dot(x, g, min_kernel_elems=0)),
        rtol=1e-5, atol=1e-3)
    for a, b, name in zip(
            round_stats.round_stats(x, g),
            round_stats.round_stats(x, g, min_kernel_elems=0),
            ("dots", "sqnorms", "sqg")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-3, err_msg=name)


def test_small_shape_fallback_trace_time_dispatch():
    """The dispatch is trace-time: a small buffer lowers with NO
    pallas_call in the jaxpr (the cliff was the launch cost, so it must
    not merely be masked), while min_kernel_elems=0 forces the kernel."""
    x = jnp.ones((8, 1024), jnp.float32)
    g = jnp.ones((1024,), jnp.float32)
    w = jnp.ones((8,), jnp.float32)
    assert not _has_pallas_call(weighted_agg.weighted_agg, w, x)
    assert not _has_pallas_call(weighted_agg.batched_dot, x, g)
    assert not _has_pallas_call(round_stats.round_stats, x, g)
    assert _has_pallas_call(weighted_agg.weighted_agg, w, x,
                            min_kernel_elems=0)
    assert _has_pallas_call(round_stats.round_stats, x, g,
                            min_kernel_elems=0)
    # above the threshold the kernel path is the default
    big = jnp.ones((32, 65536), jnp.float32)
    wb = jnp.ones((32,), jnp.float32)
    assert _has_pallas_call(weighted_agg.weighted_agg, wb, big)


def test_row_block_adapts_to_narrow_buffers():
    """_row_block keeps the f32 minimum sublane tile and never pads a
    narrow buffer to the full 128*128 chunk (16x waste at d=1024)."""
    for n, want in [(1, 8), (1024, 8), (1025, 16), (16384, 128),
                    (10**6, 128)]:
        assert weighted_agg._row_block(n) == want, n
    # padded width under the adaptive block stays within 2x of N
    for n in (1024, 5000, 20000, 70001):
        rows = weighted_agg._row_block(n)
        padded = -(-n // (rows * 128)) * rows * 128
        assert padded < 2 * max(n, 8 * 128)


def _tree(key, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "a": jax.random.normal(k1, (257, 33), dtype),
        "b": {"c": jax.random.normal(k2, (1000,), dtype),
              "d": jax.random.normal(k3, (4, 4, 4), dtype)},
    }


def test_ops_tree_dot_and_norms_matches_treemath():
    a, b = _tree(jax.random.key(0)), _tree(jax.random.key(1))
    got = ops.tree_dot_and_norms(a, b)
    want = treemath.tree_dot_and_norms(a, b)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-3)


def test_ops_tree_weighted_sum_matches_treemath():
    trees = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[_tree(jax.random.key(i)) for i in range(4)],
    )
    w = jnp.asarray([0.1, 0.2, 0.3, 0.4])
    got = ops.tree_weighted_sum(trees, w)
    want = treemath.tree_weighted_sum(trees, w)
    jax.tree.map(
        lambda g, x: np.testing.assert_allclose(
            np.asarray(g), np.asarray(x), rtol=1e-3, atol=1e-5
        ),
        got, want,
    )


def test_ops_tree_vdot_batched_matches_treemath():
    trees = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[_tree(jax.random.key(i)) for i in range(3)]
    )
    single = _tree(jax.random.key(9))
    np.testing.assert_allclose(
        np.asarray(ops.tree_vdot_batched(trees, single)),
        np.asarray(treemath.tree_vdot_batched(trees, single)), rtol=1e-3,
    )
