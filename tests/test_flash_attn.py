"""Flash-attention Pallas kernel vs the pure-jnp oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attn, ref
from repro.models import attention as A

CASES = [
    (4, 256, 64, jnp.float32, True, 64, 64),
    (2, 256, 128, jnp.float32, False, 128, 64),
    (2, 512, 64, jnp.float32, True, 128, 128),
    (3, 128, 64, jnp.bfloat16, True, 64, 32),
]


@pytest.mark.parametrize("BH,T,d,dtype,causal,bq,bk", CASES)
def test_flash_matches_oracle(BH, T, d, dtype, causal, bq, bk):
    q = jax.random.normal(jax.random.key(0), (BH, T, d), dtype)
    k = jax.random.normal(jax.random.key(1), (BH, T, d), dtype)
    v = jax.random.normal(jax.random.key(2), (BH, T, d), dtype)
    got = flash_attn.flash_attention(q, k, v, causal, bq, bk)
    want = ref.flash_attention(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_gqa_wrapper_matches_model_attention():
    B, T, H, G, hd = 2, 128, 4, 2, 64
    q = jax.random.normal(jax.random.key(3), (B, T, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(4), (B, T, G, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(5), (B, T, G, hd), jnp.float32)
    got = flash_attn.gqa_flash(q, k, v, blk_q=64, blk_k=64)
    scores = A._gqa_scores(q, k, 1.0 / np.sqrt(hd))
    probs = A._masked_softmax(scores, A.full_mask(T, T, True, 0))
    want = A._gqa_out(probs, v).reshape(B, T, H, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_first_row_attends_only_self():
    q = jnp.ones((1, 64, 64))
    k = jax.random.normal(jax.random.key(0), (1, 64, 64))
    v = jax.random.normal(jax.random.key(1), (1, 64, 64))
    out = flash_attn.flash_attention(q, k, v, True, 32, 32)
    np.testing.assert_allclose(np.asarray(out[0, 0]), np.asarray(v[0, 0]),
                               rtol=1e-5)


def test_flash_backend_in_model_matches_xla_incl_grads():
    import dataclasses

    from repro.configs import registry
    from repro.models import transformer

    cfg = registry.smoke("starcoder2-15b")
    fcfg = dataclasses.replace(cfg, attention_impl="flash")
    params = transformer.init_params(jax.random.key(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 64), 0,
                                          cfg.vocab_size)}
    l1, _, _ = transformer.forward(params, cfg, batch, mode="train")
    l2, _, _ = transformer.forward(params, fcfg, batch, mode="train")
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=2e-4, rtol=2e-4)
    g1 = jax.grad(transformer.loss_fn)(params, cfg, batch)
    g2 = jax.grad(transformer.loss_fn)(params, fcfg, batch)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=3e-4, rtol=3e-3),
        g1, g2,
    )
