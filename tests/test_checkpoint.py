"""Checkpoint layer tests: the io round-trip bugfix regressions, the
RoundState <-> nested-dict codec (structure per FLConfig, elastic-K), the
checkpoint-directory machinery (atomicity, latest pointer, retention),
and the tier-1 gate of the whole layer — kill/resume golden invariance:
a run interrupted at any scan-block boundary and resumed from the
checkpoint reproduces the uninterrupted run's rounds-to-85% and
bit-identical final RoundState, stepwise and scanned, for the reference
f32/f32 wire and the fully quantized int4/int8 pair.
"""
import itertools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import transport
from repro.checkpoint import io as ckpt_io
from repro.core import fl
from repro.core.server import FedServer
from repro.data import synthetic


def _assert_bitexact(a, b, what=""):
    """Bitwise pytree equality (typed PRNG keys compared via key_data)."""
    assert jax.tree.structure(a) == jax.tree.structure(b), what
    flat = jax.tree_util.tree_flatten_with_path(a)[0]
    for (path, x), y in zip(flat, jax.tree.leaves(b)):
        if jax.dtypes.issubdtype(x.dtype, jax.dtypes.prng_key):
            x, y = jax.random.key_data(x), jax.random.key_data(y)
        assert x.dtype == y.dtype and x.shape == y.shape, (
            f"{what}{jax.tree_util.keystr(path)}")
        assert np.asarray(x).tobytes() == np.asarray(y).tobytes(), (
            f"{what}{jax.tree_util.keystr(path)} differs bitwise")


# ------------------------------------------------ io bugfix regressions


def test_save_load_agree_on_suffixless_path(tmp_path):
    """Regression: np.savez appends '.npz' when the path lacks it, so
    load(path) used to FileNotFoundError for the very path save(path)
    was handed."""
    p = str(tmp_path / "ckpt")  # no .npz suffix
    ckpt_io.save(p, {"a": jnp.arange(3)})
    assert ckpt_io.load(p)["a"].tolist() == [0, 1, 2]
    # the suffixed spelling finds the same file
    assert ckpt_io.load(p + ".npz")["a"].tolist() == [0, 1, 2]
    assert os.listdir(tmp_path) == ["ckpt.npz"]


def test_none_leaves_and_empty_subtrees_roundtrip(tmp_path):
    """Regression: _flatten silently dropped None leaves and empty-dict
    subtrees, so load(save(tree)) changed pytree structure for configs
    with optional RoundState fields off."""
    tree = {"params": {"w": jnp.ones((2,))}, "ef": None, "dl_ef": None,
            "nested": {"inner": None}, "empty": {}}
    p = str(tmp_path / "t.npz")
    ckpt_io.save(p, tree)
    back = ckpt_io.load(p)
    assert back["ef"] is None and back["dl_ef"] is None
    assert back["nested"]["inner"] is None
    assert back["empty"] == {}
    none_leaf = lambda x: x is None  # noqa: E731
    assert (jax.tree.structure(back, is_leaf=none_leaf)
            == jax.tree.structure(tree, is_leaf=none_leaf))


def test_slash_in_key_rejected(tmp_path):
    """Regression: a '/' inside a dict key used to corrupt the flattened
    path (splitting one field into a fake subtree on load)."""
    with pytest.raises(ValueError, match="a/b"):
        ckpt_io.save(str(tmp_path / "t"), {"a/b": jnp.zeros(1)})
    with pytest.raises(ValueError, match="separator"):
        ckpt_io.save(str(tmp_path / "t"), {"sub": {"x/y": jnp.zeros(1)}})


def test_typed_prng_key_roundtrip(tmp_path):
    """Regression: jax.random.key(...) arrays crashed np.asarray in
    _flatten; they now ship as key_data + an impl tag and come back as
    typed keys producing the identical stream."""
    key = jax.random.key(7)
    p = str(tmp_path / "k.npz")
    ckpt_io.save(p, {"rng": key, "nested": {"k2": jax.random.fold_in(key, 3)}})
    back = ckpt_io.load(p)
    for got, want in ((back["rng"], key),
                      (back["nested"]["k2"], jax.random.fold_in(key, 3))):
        assert jax.dtypes.issubdtype(got.dtype, jax.dtypes.prng_key)
        np.testing.assert_array_equal(jax.random.key_data(got),
                                      jax.random.key_data(want))
        np.testing.assert_array_equal(jax.random.uniform(got, (4,)),
                                      jax.random.uniform(want, (4,)))


def test_old_style_uint32_key_loads_as_raw_array(tmp_path):
    """Old-style raw uint32 keys are plain arrays on the wire — the codec
    (state_from_tree) wraps them back into typed keys."""
    raw = jax.random.PRNGKey(3)  # uint32 (2,)
    p = str(tmp_path / "k.npz")
    ckpt_io.save(p, {"rng": raw})
    back = ckpt_io.load(p)["rng"]
    assert back.dtype == jnp.uint32 and back.shape == (2,)


def test_module_docstring_points_at_the_codec():
    """Regression: the docstring referenced core.server.ServerState.to_tree,
    gone since the PR 5 RoundState refactor."""
    assert "ServerState.to_tree" not in (ckpt_io.__doc__ or "")
    assert "state_to_tree" in ckpt_io.__doc__
    assert hasattr(fl, "state_to_tree") and hasattr(fl, "state_from_tree")


def test_all_leaf_dtypes_roundtrip_exactly(tmp_path):
    rng = np.random.default_rng(0)
    tree = {
        "f32": jnp.asarray(rng.normal(size=(3, 4)).astype(np.float32)),
        "f16": jnp.asarray(rng.normal(size=(5,)).astype(np.float16)),
        "bf16": jnp.asarray(rng.normal(size=(4, 2)), jnp.bfloat16),
        "i8": jnp.asarray(rng.integers(-128, 127, (7,)), jnp.int8),
        "u8": jnp.asarray(rng.integers(0, 255, (6,)), jnp.uint8),
        "i32": jnp.asarray(rng.integers(-2**31, 2**31 - 1, (3,)), jnp.int32),
        "u32": jnp.asarray(rng.integers(0, 2**32 - 1, (3,)), jnp.uint32),
        "bool": jnp.asarray([True, False, True]),
        "scalar": jnp.float32(3.5),
        "key": jax.random.key(11),
    }
    p = str(tmp_path / "dtypes.npz")
    ckpt_io.save(p, tree)
    _assert_bitexact(ckpt_io.load(p), tree)


# ------------------------------------------ checkpoint-directory layer


def test_save_checkpoint_latest_pointer_and_retention(tmp_path):
    d = str(tmp_path / "run")
    for step in (2, 4, 6, 8):
        ckpt_io.save_checkpoint(d, step, {"x": jnp.int32(step)}, keep=2)
    steps = [s for s, _ in ckpt_io.list_checkpoints(d)]
    assert steps == [6, 8]  # retention kept the newest 2
    step, tree = ckpt_io.load_latest(d)
    assert step == 8 and int(tree["x"]) == 8
    assert not [f for f in os.listdir(d) if ".tmp." in f]  # atomic writes


def test_latest_pointer_survives_torn_writer(tmp_path):
    """A writer killed mid-save leaves only temp garbage / a stale
    pointer; load_latest must still resolve a complete archive."""
    d = str(tmp_path / "run")
    ckpt_io.save_checkpoint(d, 3, {"x": jnp.int32(3)})
    # torn archive write: garbage tmp file must be ignored
    with open(os.path.join(d, "ckpt_00000009.npz.tmp.999"), "wb") as f:
        f.write(b"partial garbage")
    # stale pointer: names an archive that never finished its rename
    with open(os.path.join(d, "latest"), "w") as f:
        f.write("ckpt_00000009.npz\n")
    step, tree = ckpt_io.load_latest(d)
    assert step == 3 and int(tree["x"]) == 3
    assert ckpt_io.load_latest(str(tmp_path / "nowhere")) is None


# ------------------------------------------------------ RoundState codec


_PARAMS = {"w": jnp.linspace(-1.0, 1.0, 8).reshape(4, 2),
           "b": jnp.asarray([0.5, -0.25], jnp.bfloat16)}


def _combo_cfg(ef, dlef, dld, num_clients=5):
    return fl.FLConfig(
        num_clients=num_clients, clients_per_round=3, local_steps=2,
        transport="int8" if ef else "f32",
        downlink="int8" if (dlef or dld) else "f32",
        error_feedback=ef, downlink_error_feedback=dlef,
        downlink_delta=dld)


@pytest.mark.parametrize("ef,dlef,dld",
                         list(itertools.product([False, True], repeat=3)))
def test_state_tree_roundtrip_every_optional_combo(tmp_path, ef, dlef, dld):
    """save(state_to_tree) -> load -> state_from_tree is the identity for
    every optional-field combination: same pytree structure as
    init_round_state and bitwise-equal leaves."""
    cfg = _combo_cfg(ef, dlef, dld)
    state = fl.init_round_state(cfg, _PARAMS, seed=3)
    p = str(tmp_path / "state")
    ckpt_io.save(p, fl.state_to_tree(state))
    back = fl.state_from_tree(cfg, ckpt_io.load(p))
    _assert_bitexact(back, state)


def test_state_from_tree_rejects_optional_field_mismatch(tmp_path):
    cfg_ef = _combo_cfg(True, False, False)
    tree = fl.state_to_tree(fl.init_round_state(cfg_ef, _PARAMS))
    with pytest.raises(ValueError, match="error_feedback=False"):
        fl.state_from_tree(_combo_cfg(False, False, False), tree)
    tree_plain = fl.state_to_tree(
        fl.init_round_state(_combo_cfg(False, False, False), _PARAMS))
    with pytest.raises(ValueError, match="no 'ef'"):
        fl.state_from_tree(cfg_ef, tree_plain)


def test_state_from_tree_validates_shape_and_dtype():
    cfg = _combo_cfg(True, False, False)
    tree = fl.state_to_tree(fl.init_round_state(cfg, _PARAMS))
    bad = dict(tree, prev_delta={"w": tree["prev_delta"]["w"],
                                 "b": jnp.zeros((3,), jnp.float32)})
    with pytest.raises(ValueError, match="prev_delta"):
        fl.state_from_tree(cfg, bad)
    # EF width must match THIS model's parameter count
    bad = dict(tree, ef=jnp.zeros((cfg.num_clients, 3), jnp.float32))
    with pytest.raises(ValueError, match="ef"):
        fl.state_from_tree(cfg, bad)
    with pytest.raises(ValueError, match="lacks required"):
        fl.state_from_tree(cfg, {k: v for k, v in tree.items()
                                 if k != "rng"})


def test_state_from_tree_rejects_legacy_prev_broadcast():
    """A checkpoint written by the shared-vector revision carries
    'prev_broadcast' instead of 'bcast' — its per-client decode bases
    are unrecoverable, so the codec refuses with a pointed error rather
    than silently resyncing every client."""
    cfg = _combo_cfg(False, False, True)
    tree = fl.state_to_tree(fl.init_round_state(cfg, _PARAMS))
    bcast = tree.pop("bcast")
    tree["prev_broadcast"] = bcast["head"]
    with pytest.raises(ValueError, match="prev_broadcast"):
        fl.state_from_tree(cfg, tree)


def test_state_from_tree_wraps_old_style_raw_key():
    cfg = _combo_cfg(False, False, False)
    tree = fl.state_to_tree(fl.init_round_state(cfg, _PARAMS))
    tree["rng"] = np.asarray(jax.random.PRNGKey(5))  # raw uint32 (2,)
    back = fl.state_from_tree(cfg, tree)
    assert jax.dtypes.issubdtype(back.rng.dtype, jax.dtypes.prng_key)
    np.testing.assert_array_equal(jax.random.key_data(back.rng),
                                  np.asarray(jax.random.PRNGKey(5)))


# ---------------------------------------------------- elastic-K restore


def test_elastic_k_repad_semantics():
    """K=10 -> 13: surviving clients' angle/EF rows restore bit-exactly,
    new clients start from zero residual and unseen angle. K=10 -> 7:
    departed clients' slots are dropped."""
    n = fl.param_count(_PARAMS)
    cfg10 = _combo_cfg(True, False, False, num_clients=10)
    st = fl.init_round_state(cfg10, _PARAMS, seed=1)
    st = st._replace(
        angle=fl.AngleState(
            smoothed=jnp.arange(10, dtype=jnp.float32) * 0.1,
            count=jnp.arange(10, dtype=jnp.int32)),
        ef=jnp.tile(jnp.arange(10, dtype=jnp.float32)[:, None], (1, n)))
    tree = fl.state_to_tree(st)

    b13 = fl.state_from_tree(_combo_cfg(True, False, False, 13), tree)
    assert b13.angle.smoothed.shape == (13,) and b13.ef.shape == (13, n)
    np.testing.assert_array_equal(b13.angle.smoothed[:10], st.angle.smoothed)
    np.testing.assert_array_equal(b13.angle.count[:10], st.angle.count)
    np.testing.assert_array_equal(np.asarray(b13.ef)[:10], np.asarray(st.ef))
    assert np.all(np.asarray(b13.angle.smoothed[10:]) == 0.0)
    assert np.all(np.asarray(b13.angle.count[10:]) == 0)
    assert np.all(np.asarray(b13.ef)[10:] == 0.0)

    b7 = fl.state_from_tree(_combo_cfg(True, False, False, 7), tree)
    assert b7.angle.count.shape == (7,) and b7.ef.shape == (7, n)
    np.testing.assert_array_equal(b7.angle.count,
                                  np.asarray(st.angle.count)[:7])
    np.testing.assert_array_equal(np.asarray(b7.ef), np.asarray(st.ef)[:7])
    # the K-independent pieces are untouched
    _assert_bitexact(b7.params, st.params)
    np.testing.assert_array_equal(jax.random.key_data(b7.rng),
                                  jax.random.key_data(st.rng))


def test_elastic_k_bcast_repad_semantics():
    """The broadcast-delta state is part K-dependent (ver) and part
    model-dependent (ring/head/head_ver). K=10 -> 13: surviving clients
    keep their last-pulled version bit-exactly, new clients start
    NEVER_PULLED (they must take a full resync). K=10 -> 7: departed
    clients' version rows are dropped. The ring, head, and head_ver are
    K-independent and restore bit-exactly in both directions."""
    cfg10 = _combo_cfg(False, False, True, num_clients=10)
    st = fl.init_round_state(cfg10, _PARAMS, seed=1)
    n = fl.param_count(_PARAMS)
    st = st._replace(bcast=st.bcast._replace(
        ring=st.bcast.ring.at[0].set(0.125),
        head=jnp.full((n,), 0.5, jnp.float32),
        head_ver=jnp.int32(4),
        ver=jnp.arange(10, dtype=jnp.int32) - 1))  # client 0 never pulled
    tree = fl.state_to_tree(st)

    b13 = fl.state_from_tree(_combo_cfg(False, False, True, 13), tree)
    assert b13.bcast.ver.shape == (13,)
    np.testing.assert_array_equal(np.asarray(b13.bcast.ver)[:10],
                                  np.asarray(st.bcast.ver))
    assert np.all(np.asarray(b13.bcast.ver)[10:]
                  == transport.downlink.NEVER_PULLED)
    _assert_bitexact((b13.bcast.ring, b13.bcast.head, b13.bcast.head_ver),
                     (st.bcast.ring, st.bcast.head, st.bcast.head_ver))

    b7 = fl.state_from_tree(_combo_cfg(False, False, True, 7), tree)
    assert b7.bcast.ver.shape == (7,)
    np.testing.assert_array_equal(np.asarray(b7.bcast.ver),
                                  np.asarray(st.bcast.ver)[:7])
    _assert_bitexact((b7.bcast.ring, b7.bcast.head, b7.bcast.head_ver),
                     (st.bcast.ring, st.bcast.head, st.bcast.head_ver))


# --------------------------------------- kill/resume golden invariance


@pytest.fixture(scope="module")
def golden_task():
    """The golden-convergence task: 12k-train image problem, 5 IID +
    non-IID one-class nodes (600 samples each), MLR, rounds-to-85%."""
    return synthetic.make_image_task(seed=0, num_train=12000, num_test=2000)


def _golden_server(task, cfg, num_nodes=None, seed=0):
    train, test = task
    spec = [("iid", None)] * 5 + [("xclass", 1)] * 8
    nodes = synthetic.make_federated(
        train, spec[:num_nodes or cfg.num_clients],
        samples_per_node=600, seed=1)
    return FedServer("mlr", cfg, nodes, test, batch_size=50, seed=seed)


WIRES = [("f32", "f32"), ("int4", "int8")]


@pytest.mark.parametrize("uplink,downlink", WIRES)
def test_kill_resume_scanned_invariance(tmp_path, golden_task, uplink,
                                        downlink):
    """Tier-1 gate: a scanned run killed at ANY block boundary and
    resumed from the checkpoint reproduces the uninterrupted run —
    bit-identical final RoundState (params, angle, EF, rng, round) and
    the identical per-round accuracy trace, hence identical
    rounds-to-85%."""
    rounds, block, target = 6, 2, 0.85
    cfg = fl.FLConfig(num_clients=10, clients_per_round=10, local_steps=12,
                      method="fedadp", engine="flat", transport=uplink,
                      downlink=downlink, base_lr=0.05)
    d = str(tmp_path / "ckpts")
    ref = _golden_server(golden_task, cfg)
    h_ref = ref.run_scanned(rounds, eval_every=1, block=block,
                            ckpt_dir=d, ckpt_keep=0)
    acc_ref = np.asarray(h_ref.accuracy)
    hits = np.flatnonzero(acc_ref >= target)
    assert hits.size, f"golden task no longer reaches {target}: {acc_ref}"
    rtt_ref = int(hits[0]) + 1

    edges = {step: path for step, path in ckpt_io.list_checkpoints(d)}
    assert sorted(edges) == [2, 4, 6]  # every block boundary snapshotted
    for edge in (2, 4):  # kill points strictly inside the run
        res = _golden_server(golden_task, cfg)
        assert res.restore(edges[edge]) == edge
        h_res = res.run_scanned(rounds - edge, eval_every=1, block=block)
        # identical accuracy tail => identical rounds-to-target
        np.testing.assert_array_equal(np.asarray(h_res.accuracy),
                                      acc_ref[edge:])
        _assert_bitexact(res.state, ref.state, what=f"edge {edge}: ")

    # absolute rounds-to-target bookkeeping through a resumed early-exit
    res = _golden_server(golden_task, cfg)
    res.restore(edges[2])
    h = res.run_scanned(rounds - 2, target_acc=target, eval_every=1,
                        block=block)
    assert h.rounds_to_target == rtt_ref


def test_kill_resume_stepwise_invariance(tmp_path, golden_task):
    """The stepwise path (one jit dispatch per round) restores just as
    bit-exactly: 3 rounds + save + restore + 3 rounds == 6 rounds."""
    cfg = fl.FLConfig(num_clients=10, clients_per_round=10, local_steps=12,
                      method="fedadp", engine="flat", base_lr=0.05)
    ref = _golden_server(golden_task, cfg)
    for _ in range(6):
        ref.step()

    part = _golden_server(golden_task, cfg)
    for _ in range(3):
        part.step()
    d = str(tmp_path / "ckpts")
    part.save_checkpoint(d)
    res = _golden_server(golden_task, cfg)
    assert res.restore(d) == 3
    for _ in range(3):
        res.step()
    assert res.round == 6
    _assert_bitexact(res.state, ref.state)


def test_kill_resume_subset_selection_downlink_delta(tmp_path,
                                                     golden_task):
    """Kill/resume with the per-client broadcast state in play: 5-of-10
    subset selection + delta-encoded downlink, so the checkpoint carries
    a mid-flight ring, chain head, and staggered per-client versions.
    The resumed run must reproduce the uninterrupted one bit-exactly —
    state AND accuracy trace (the 85%-target assertion is owned by the
    full-participation legs; subset selection converges slower)."""
    rounds, block = 6, 2
    cfg = fl.FLConfig(num_clients=10, clients_per_round=5, local_steps=12,
                      method="fedadp", engine="flat", downlink="int8",
                      downlink_delta=True, base_lr=0.05)
    d = str(tmp_path / "ckpts")
    ref = _golden_server(golden_task, cfg)
    h_ref = ref.run_scanned(rounds, eval_every=1, block=block,
                            ckpt_dir=d, ckpt_keep=0)
    # the checkpointed state really is mid-stream per-client state:
    # chain advanced every round, versions staggered by selection
    assert int(ref.state.bcast.head_ver) == rounds - 1
    ver = np.asarray(ref.state.bcast.ver)
    assert len(set(ver.tolist())) > 1, f"degenerate schedule: {ver}"

    edges = dict(ckpt_io.list_checkpoints(d))
    for edge in (2, 4):
        res = _golden_server(golden_task, cfg)
        assert res.restore(edges[edge]) == edge
        h_res = res.run_scanned(rounds - edge, eval_every=1, block=block)
        np.testing.assert_array_equal(np.asarray(h_res.accuracy),
                                      np.asarray(h_ref.accuracy)[edge:])
        _assert_bitexact(res.state, ref.state, what=f"edge {edge}: ")


def test_elastic_k_restore_converges(tmp_path, golden_task):
    """Acceptance: a K=10 checkpoint restores into K=13 and K=7 fleets,
    new clients start unseen (EF zero / angle count zero), and both
    resumed fleets still reach the 85% target."""
    mk = lambda k: fl.FLConfig(  # noqa: E731
        num_clients=k, clients_per_round=k, local_steps=12,
        method="fedadp", engine="flat", transport="int8",
        error_feedback=True, base_lr=0.05)
    d = str(tmp_path / "ckpts")
    s10 = _golden_server(golden_task, mk(10))
    s10.run_scanned(2, eval_every=0, block=2, ckpt_dir=d)

    for k in (13, 7):
        sk = _golden_server(golden_task, mk(k))
        assert sk.restore(d) == 2
        counts = np.asarray(sk.state.angle.count)
        ef = np.asarray(sk.state.ef)
        if k > 10:  # new clients: unseen angle, zero residual
            assert np.all(counts[10:] == 0) and np.all(ef[10:] == 0.0)
        assert np.all(counts[:min(k, 10)] == 2)  # survivors keep history
        h = sk.run_scanned(40, target_acc=0.85, eval_every=1, block=4)
        assert h.rounds_to_target is not None, f"K={k} failed to converge"


def test_kill_resume_flat_sharded_8device_subprocess(tmp_path):
    """The checkpoint layer composes with the client-sharded engine: on
    an 8-way host-device mesh, kill/resume of a scanned flat_sharded run
    restores bit-exactly."""
    prog = textwrap.dedent("""
        import os, sys, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        from repro.checkpoint import io as ckpt_io
        from repro.core import fl
        from repro.core.server import FedServer
        from repro.data import synthetic
        train, test = synthetic.make_image_task(seed=0, num_train=3000,
                                                num_test=400)
        nodes = synthetic.make_federated(
            train, [("iid", None)] * 4 + [("xclass", 1)] * 4,
            samples_per_node=200, seed=1)
        mesh = jax.make_mesh((8,), ("data",))
        cfg = fl.FLConfig(num_clients=8, clients_per_round=8, local_steps=4,
                          method="fedadp", engine="flat_sharded",
                          transport="int8", error_feedback=True,
                          base_lr=0.05)
        d = tempfile.mkdtemp()
        mk = lambda: FedServer("mlr", cfg, nodes, test, batch_size=50,
                               seed=0, mesh=mesh)
        ref = mk()
        ref.run_scanned(4, eval_every=1, block=2, ckpt_dir=d)
        res = mk()
        step, tree = ckpt_io.load_latest(d)
        assert step == 4
        res.restore(ckpt_io.checkpoint_path(d, 2))
        res.run_scanned(2, eval_every=1, block=2)
        for a, b in zip(jax.tree.leaves(ref.state), jax.tree.leaves(res.state)):
            if jax.dtypes.issubdtype(a.dtype, jax.dtypes.prng_key):
                a, b = jax.random.key_data(a), jax.random.key_data(b)
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
        print("RESUME_SHARDED_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "RESUME_SHARDED_OK" in out.stdout, out.stderr[-2000:]
