"""shard_map FedAdp aggregation vs the pjit/treemath path.

Covers both engines: "tree" (per-leaf reductions, model-axis sharding
allowed) and "flat" (client-row-sharded (K, N) buffer through the fused
Pallas kernels). The multi-device equivalence check runs in a subprocess
(the test session itself is pinned to 1 device; the dry-run
placeholder-device trick is reserved for repro.launch.dryrun).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import fl_shard_map, treemath, weighting


def _reference(deltas, sizes, sm_prev, cnt_prev, alpha=5.0):
    psi = weighting.fedavg_weights(sizes)
    g_avg = treemath.tree_weighted_sum(deltas, psi)
    theta = weighting.instantaneous_angle(
        treemath.tree_vdot_batched(deltas, g_avg),
        treemath.tree_sqnorm_batched(deltas),
        treemath.tree_sqnorm(g_avg),
    )
    cnt = cnt_prev.astype(jnp.float32) + 1
    sm = ((cnt - 1) * sm_prev + theta) / cnt
    w = weighting.fedadp_weights(sm, sizes, alpha)
    return treemath.tree_weighted_sum(deltas, w), theta, w


def test_single_device_mesh_matches_reference():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    K = 4
    deltas = {
        "a": jax.random.normal(jax.random.key(0), (K, 8, 6)),
        "b": jax.random.normal(jax.random.key(1), (K, 16)),
    }
    pspecs = {"a": P("data", None, "model"), "b": P("data", None)}
    sizes = jnp.asarray([10.0, 20.0, 30.0, 40.0])
    sm_prev = jnp.asarray([0.5, 0.2, 0.9, 0.4])
    cnt_prev = jnp.asarray([1, 2, 0, 3], jnp.int32)
    agg = fl_shard_map.fedadp_aggregate(mesh, pspecs, alpha=5.0)
    with mesh:
        delta, theta, _, w = jax.jit(agg)(deltas, sizes, sm_prev, cnt_prev)
    dref, tref, wref = _reference(deltas, sizes, sm_prev, cnt_prev)
    np.testing.assert_allclose(np.asarray(theta), np.asarray(tref), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(w), np.asarray(wref), rtol=1e-5)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                rtol=1e-4, atol=1e-6),
        delta, dref,
    )


def test_single_device_flat_engine_matches_reference():
    """engine="flat" on a 1x1 mesh: the kernel path with no-op psums must
    reproduce the treemath reference."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    K = 4
    deltas = {
        "a": jax.random.normal(jax.random.key(0), (K, 8, 6)),
        "b": jax.random.normal(jax.random.key(1), (K, 16)),
    }
    pspecs = {"a": P("data", None, None), "b": P("data", None)}
    sizes = jnp.asarray([10.0, 20.0, 30.0, 40.0])
    sm_prev = jnp.asarray([0.5, 0.2, 0.9, 0.4])
    cnt_prev = jnp.asarray([1, 2, 0, 3], jnp.int32)
    agg = fl_shard_map.fedadp_aggregate(mesh, pspecs, alpha=5.0,
                                        engine="flat")
    with mesh:
        delta, theta, _, w = jax.jit(agg)(deltas, sizes, sm_prev, cnt_prev)
    dref, tref, wref = _reference(deltas, sizes, sm_prev, cnt_prev)
    np.testing.assert_allclose(np.asarray(theta), np.asarray(tref), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(w), np.asarray(wref), rtol=1e-5)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                rtol=1e-4, atol=1e-6),
        delta, dref,
    )


def test_flat_engine_rejects_model_sharded_specs():
    """Model-axis-sharded leaves cannot ravel into contiguous client rows;
    the flat engine must refuse them at build time."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pspecs = {"a": P("data", None, "model")}
    with pytest.raises(ValueError, match="client-only"):
        fl_shard_map.fedadp_aggregate(mesh, pspecs, alpha=5.0, engine="flat")


def test_unknown_shard_map_engine_rejected():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="engine"):
        fl_shard_map.fedadp_aggregate(mesh, {"a": P("data", None)},
                                      alpha=5.0, engine="nope")


def test_multi_device_mesh_matches_reference_subprocess():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import fl_shard_map, treemath, weighting
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        K = 4
        deltas = {"a": jax.random.normal(jax.random.key(0), (K, 8, 6)),
                  "b": jax.random.normal(jax.random.key(1), (K, 16))}
        pspecs = {"a": P("data", None, "model"), "b": P("data", None)}
        sizes = jnp.asarray([10., 20., 30., 40.])
        sm = jnp.asarray([.5, .2, .9, .4]); cnt = jnp.asarray([1,2,0,3], jnp.int32)
        agg = fl_shard_map.fedadp_aggregate(mesh, pspecs, alpha=5.0)
        with mesh:
            delta, theta, _, w = jax.jit(agg)(deltas, sizes, sm, cnt)
        psi = weighting.fedavg_weights(sizes)
        g = treemath.tree_weighted_sum(deltas, psi)
        tref = weighting.instantaneous_angle(
            treemath.tree_vdot_batched(deltas, g),
            treemath.tree_sqnorm_batched(deltas), treemath.tree_sqnorm(g))
        c = cnt.astype(jnp.float32)+1
        wref = weighting.fedadp_weights(((c-1)*sm + tref)/c, sizes, 5.0)
        dref = treemath.tree_weighted_sum(deltas, wref)
        np.testing.assert_allclose(np.asarray(theta), np.asarray(tref), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(w), np.asarray(wref), rtol=1e-5)
        jax.tree.map(lambda a,b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6), delta, dref)
        # flat engine: same math through client-row-sharded fused kernels
        # (client-only pspecs; the "model" axis sees the buffer replicated)
        pspecs2 = {"a": P("data", None, None), "b": P("data", None)}
        agg2 = fl_shard_map.fedadp_aggregate(mesh, pspecs2, alpha=5.0,
                                             engine="flat")
        with mesh:
            d2, t2, _, w2 = jax.jit(agg2)(deltas, sizes, sm, cnt)
        np.testing.assert_allclose(np.asarray(t2), np.asarray(tref), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(w2), np.asarray(wref), rtol=1e-5)
        jax.tree.map(lambda a,b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6), d2, dref)
        print("SHARD_MAP_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARD_MAP_OK" in out.stdout, out.stderr[-2000:]
