"""Optional-hypothesis shim for the property tests.

`from _hypothesis_compat import hypothesis, hnp, st` gives the real
modules when hypothesis is installed; otherwise `hypothesis.given`
becomes a skip marker and the strategy modules become inert stand-ins
(strategies are built at module-import time, so attribute access and
calls must not raise). Non-property tests in the same module keep
running either way.
"""
from __future__ import annotations

import pytest

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st

    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

    class _InertStrategy:
        """Absorbs strategy construction: any attribute or call -> itself."""

        def __getattr__(self, _name):
            return self

        def __call__(self, *_args, **_kwargs):
            return self

    class _HypothesisStub:
        HealthCheck = _InertStrategy()
        settings = _InertStrategy()

        @staticmethod
        def given(*_args, **_kwargs):
            return pytest.mark.skip(reason="hypothesis not installed")

    hypothesis = _HypothesisStub()
    st = _InertStrategy()
    hnp = _InertStrategy()
