"""Round-level telemetry layer tests.

The central pins: (1) the OFF path is really off — `telemetry=None`
keeps the engines' metrics keyset exactly the pre-telemetry set and the
trajectory bit-identical to a telemetry="node" run, and the compiled
step's jaxpr is a pure function of the config (no ambient telemetry
state); (2) scanned and stepwise runs stream IDENTICAL telemetry through
the one shared adapter (`sinks.emit_round_block`) — per-round per-node
angle/weight rows match to 1e-5; (3) a JSONL stream alone reproduces the
run's rounds-to-target (the Table-I claim is auditable from telemetry);
(4) the in-scan eval sentinel is a pinned constant masked by every
reader.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import driver, fl
from repro.data import synthetic
from repro.telemetry import report, schema, sinks

FLSTAT = os.path.join(os.path.dirname(__file__), "..", "scripts",
                      "flstat.py")

# metrics every round carries with telemetry OFF — the exact pre-telemetry
# keyset. Growing it is an intentional act (and a jaxpr change); the
# telemetry layer must never leak tel/* keys into the off path.
OFF_KEYS_SYNC = {"loss", "theta", "theta_smoothed", "weights", "divergence",
                 "lr", "cos", "expected_contribution", "accuracy"}
TEL_KEYS_SYNC = {"tel/nodes", "tel/cohort", "tel/weight_entropy",
                 "tel/bytes_up", "tel/bytes_down"}


def _task(n_nodes=4, samples=100):
    train, test = synthetic.make_image_task(seed=0, num_train=1500,
                                            num_test=200)
    nodes = synthetic.make_federated(
        train, [("iid", None)] * (n_nodes // 2)
        + [("xclass", 1)] * (n_nodes - n_nodes // 2),
        samples_per_node=samples, seed=1)
    return nodes, test


def _server(cfg, seed=0, **kw):
    nodes, test = _task(cfg.num_clients)
    return repro.FedServer("mlr", cfg, nodes, test, batch_size=50,
                           seed=seed, **kw)


def _cfg(**kw):
    base = dict(num_clients=4, clients_per_round=4, local_steps=2,
                method="fedadp", base_lr=0.05, telemetry="node")
    base.update(kw)
    return fl.FLConfig(**base)


# --------------------------------------------------- off path is off


def test_validate_rejects_unknown_telemetry():
    with pytest.raises(ValueError, match="unknown telemetry"):
        _cfg(telemetry="verbose").validate()


def test_off_keyset_is_exactly_the_pre_telemetry_set():
    m_off = _server(_cfg(telemetry=None)).step(eval_every=1)
    assert set(m_off) == OFF_KEYS_SYNC
    m_on = _server(_cfg()).step(eval_every=1)
    assert set(m_on) == OFF_KEYS_SYNC | TEL_KEYS_SYNC


def test_telemetry_on_off_trajectories_bit_identical():
    """telemetry="node" only ADDS metrics — params, angles, RNG advance
    bit-for-bit the same with it on or off."""
    s_on, s_off = _server(_cfg()), _server(_cfg(telemetry=None))
    for _ in range(3):
        m_on, m_off = s_on.step(eval_every=2), s_off.step(eval_every=2)
    for k in OFF_KEYS_SYNC:
        np.testing.assert_array_equal(np.asarray(m_on[k]),
                                      np.asarray(m_off[k]), err_msg=k)
    def host(x):
        if jnp.issubdtype(x.dtype, jax.dtypes.prng_key):
            x = jax.random.key_data(x)
        return np.asarray(x)

    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(host(a), host(b)),
        s_on.state, s_off.state)


def test_off_jaxpr_is_a_pure_function_of_the_config():
    """Two independently built telemetry=None steps lower to the same
    jaxpr — no ambient sink/span state can leak into the compiled path —
    and a config derived by switching telemetry OFF is indistinguishable
    from one born off."""
    import dataclasses

    s1 = _server(_cfg(telemetry=None))
    s2 = _server(dataclasses.replace(_cfg(), telemetry=None))
    args = (s1.state, jnp.int32(1))
    j1 = str(jax.make_jaxpr(s1._step_fn)(*args))
    j2 = str(jax.make_jaxpr(s2._step_fn)(*args))
    assert j1 == j2
    assert "tel/" not in j1


# ------------------------------------- scanned == stepwise telemetry


@pytest.mark.parametrize("engine", ["tree", "flat"])
def test_scanned_stream_matches_stepwise_stream(engine):
    """Acceptance: the scanned run emits per-round per-node angle+weight
    rows matching the stepwise run to 1e-5, through the SAME adapter."""
    cfg = _cfg(engine=engine)
    s_step, s_scan = _server(cfg), _server(cfg)
    k_step, k_scan = sinks.MemorySink(), sinks.MemorySink()
    s_step.run(6, eval_every=2, mode="stepwise", sink=k_step)
    s_scan.run(6, eval_every=2, mode="scanned", block=4, sink=k_scan)
    schema.validate_events(k_step.events)
    schema.validate_events(k_scan.events)
    for kind in ("round", "node", "summary"):
        a, b = k_step.of_type(kind), k_scan.of_type(kind)
        assert len(a) == len(b), kind
        for ea, eb in zip(a, b):
            assert set(ea) == set(eb), kind
            for f, va in ea.items():
                vb = eb[f]
                if isinstance(va, float) and va is not None:
                    assert abs(va - vb) < 1e-5, (kind, f, ea, eb)
                else:
                    assert va == vb, (kind, f, ea, eb)
    # six rounds, four nodes each
    assert len(k_scan.of_type("round")) == 6
    assert len(k_scan.of_type("node")) == 24


def test_flat_sharded_8device_stream_matches_stepwise():
    """The telemetry metrics survive the client-sharded shard_map engine:
    on an 8-way host mesh the scanned stream matches stepwise to 1e-5."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        import repro
        from repro.core import fl
        from repro.data import synthetic
        from repro.telemetry import schema, sinks
        train, test = synthetic.make_image_task(seed=0, num_train=1500,
                                                num_test=200)
        nodes = synthetic.make_federated(
            train, [("iid", None)] * 4 + [("xclass", 1)] * 4,
            samples_per_node=100, seed=1)
        mesh = jax.make_mesh((8,), ("data",))
        cfg = fl.FLConfig(num_clients=8, clients_per_round=8, local_steps=2,
                          method="fedadp", engine="flat_sharded",
                          base_lr=0.05, telemetry="node")
        servers = [repro.FedServer("mlr", cfg, nodes, test, batch_size=50,
                                   seed=0, mesh=mesh) for _ in range(2)]
        ks = [sinks.MemorySink(), sinks.MemorySink()]
        servers[0].run(4, eval_every=2, mode="stepwise", sink=ks[0])
        servers[1].run(4, eval_every=2, mode="scanned", block=4, sink=ks[1])
        for k in ks:
            schema.validate_events(k.events)
        a, b = ks[0].of_type("node"), ks[1].of_type("node")
        assert len(a) == len(b) == 4 * 8, (len(a), len(b))
        for ea, eb in zip(a, b):
            assert (ea["round"], ea["node"]) == (eb["round"], eb["node"])
            for f in ("theta", "theta_smoothed", "weight"):
                assert abs(ea[f] - eb[f]) < 1e-5, (ea, eb, f)
        print("SHARDED_TELEMETRY_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARDED_TELEMETRY_OK" in out.stdout, out.stderr[-2000:]


# ------------------------------------------------------ buffered mode


def test_buffered_stream_carries_staleness_and_occupancy():
    """Buffered ticks attribute node rows by buffer slot and carry the
    report ages, landed mask, and buffer occupancy; flush ticks satisfy
    the weight-sum invariant, non-flush ticks are exempt."""
    K, M = 4, 3
    # node 0's tick-0 report straggles 2 ticks: it misses the round-1 and
    # round-2 flushes (which proceed, 3 on-time reports >= M) and lands
    # at tick 2 aged by those two model versions.
    delays = np.zeros((3, K), np.int32)
    delays[0, 0] = 2
    drops = np.zeros((3, K), bool)
    cfg = _cfg(num_clients=K, aggregation="buffered", buffer_m=M)
    s = _server(cfg, arrival_fn=repro.fixed_arrival_schedule(delays, drops))
    sink = sinks.MemorySink()
    s.run(3, eval_every=0, mode="scanned", block=3, sink=sink)
    schema.validate_events(sink.events)
    rounds = sink.of_type("round")
    assert [e["flushed"] for e in rounds] == [1, 1, 1]
    assert all("occupancy" in e and "staleness" in e for e in rounds)
    node_rows = sink.of_type("node")
    assert all("age" in e and "landed" in e for e in node_rows)
    straggler = {e["round"]: e for e in node_rows if e["node"] == 0}
    assert [straggler[r]["landed"] for r in (1, 2, 3)] == [False, False,
                                                           True]
    assert straggler[3]["age"] == 2
    assert straggler[1]["weight"] == straggler[2]["weight"] == 0.0
    # mean landed age surfaces as the round's staleness metric
    assert rounds[2]["staleness"] == pytest.approx(2 / K)
    assert report.check_weight_sums(sink.events) == 3  # every flush tick


# ------------------------------------------- JSONL stream + sentinel


def test_jsonl_roundtrip_and_flstat_cli(tmp_path):
    """A JSONL stream written by the sink reads back validated, its
    rounds-to-target matches the in-process History, and the flstat CLI
    parses it with weight sums intact."""
    path = str(tmp_path / "telemetry.jsonl")
    sink = sinks.JSONLSink(path)
    s = _server(_cfg())
    hist = s.run(12, target_acc=0.15, eval_every=2, mode="scanned",
                 block=4, sink=sink)
    sink.close()
    events = sinks.load_events(path)
    schema.validate_events(events)
    assert events[0]["event"] == "manifest"
    assert events[0]["schema"] == schema.SCHEMA_VERSION
    assert events[0]["config"]["telemetry"] == "node"
    # the stream alone reproduces the run's headline claim
    assert hist.rounds_to_target is not None
    assert report.rounds_to_target(events, 0.15) == hist.rounds_to_target
    s_sum = report.summarize(events, target=0.15)
    assert s_sum["rounds_to_target"] == hist.rounds_to_target
    assert s_sum["spans"]["scan_block"]["count"] >= 1
    out = subprocess.run(
        [sys.executable, FLSTAT, path, "--target", "0.15", "--validate",
         "--assert-weight-sums", "--nodes"],
        capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr[-2000:]
    assert f"rounds_to_15%={hist.rounds_to_target}" in out.stdout
    assert "weight sums ok" in out.stdout


def test_percentiles_interpolate_linearly():
    """report._percentile pins: linear interpolation between bracketing
    samples (numpy's default method), not nearest-rank. The old round()
    on the fractional rank used banker's rounding — p50 of [1,2,3,4]
    came out 2 (round(1.5) -> 2... but round(0.5) -> 0), picking lower
    or upper inconsistently by parity."""
    assert report._percentile([1.0, 2.0, 3.0, 4.0], 0.50) == 2.5
    assert report._percentile([10.0, 20.0, 30.0, 40.0, 50.0], 0.90) == 46.0
    assert report._percentile([1.0, 2.0, 3.0, 4.0, 5.0], 0.99) == \
        pytest.approx(4.96)
    # exact ranks hit the sample itself
    assert report._percentile([1.0, 2.0, 3.0], 0.50) == 2.0
    assert report._percentile([7.0], 0.90) == 7.0
    assert report._percentile([3.0, 9.0], 0.0) == 3.0
    assert report._percentile([3.0, 9.0], 1.0) == 9.0
    # numpy cross-check on an awkward span list
    vals = sorted([0.03, 0.011, 0.8, 0.07, 0.22, 0.013, 0.4])
    for q in (0.5, 0.9, 0.99):
        assert report._percentile(vals, q) == \
            pytest.approx(float(np.percentile(vals, q * 100)))
    assert report._percentile([], 0.5) != report._percentile([], 0.5)  # nan


def test_partial_final_block_emits_exact_round_count():
    """rounds=10 with block=8 ends on a partial block: the stream must
    hold EXACTLY 10 round events, absolute rounds 1..10, no padding."""
    s = _server(_cfg())
    sink = sinks.MemorySink()
    s.run(10, eval_every=3, mode="scanned", block=8, sink=sink)
    rounds = sink.of_type("round")
    assert [e["round"] for e in rounds] == list(range(1, 11))
    # eval cadence survives the block split: rounds 3, 6, 9 carry a real
    # accuracy, every other round is masked to None (never the sentinel)
    acc = {e["round"]: e["accuracy"] for e in rounds}
    assert all(acc[r] is not None for r in (3, 6, 9))
    assert all(acc[r] is None for r in acc if r % 3)


def test_telemetry_every_subsamples_rounds():
    s = _server(_cfg())
    sink = sinks.MemorySink()
    s.run(8, eval_every=0, mode="scanned", block=4, sink=sink,
          telemetry_every=3)
    assert [e["round"] for e in sink.of_type("round")] == [3, 6]
    assert len(sink.of_type("node")) == 2 * 4
    assert len(sink.of_type("summary")) == 1


def test_eval_sentinel_is_pinned_and_masked():
    """The in-scan eval fill value is the named constant — an exact
    float the readers mask; changing it is a schema change."""
    assert driver.EVAL_SENTINEL == schema.EVAL_SENTINEL == -1.0
    m = _server(_cfg(telemetry=None)).step(eval_every=0)
    assert float(m["accuracy"]) == schema.EVAL_SENTINEL  # exact, ==
    assert schema.mask_accuracy(m["accuracy"]) is None
    assert not schema.is_real_accuracy(m["accuracy"])
    with pytest.raises(ValueError, match="sentinel"):
        schema.validate_event({"event": "round", "round": 1, "loss": 1.0,
                               "lr": 0.1, "divergence": 0.0,
                               "accuracy": schema.EVAL_SENTINEL})


def test_csv_sink_writes_per_node_rows(tmp_path):
    import csv

    path = str(tmp_path / "telemetry.csv")
    sink = sinks.CSVSink(path)
    _server(_cfg()).run(3, eval_every=1, mode="stepwise", sink=sink)
    sink.close()
    with open(path) as fh:
        rows = list(csv.DictReader(fh))
    assert len(rows) == 3 * 4
    assert set(rows[0]) == set(sinks.CSVSink.COLUMNS)
    w = sum(float(r["weight"]) for r in rows if r["round"] == "1")
    assert abs(w - 1.0) < 1e-5
