import os
import sys

# tests must see ONE device (the 512-device placeholder is dryrun-only)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# hypothesis is optional: property tests skip when it is absent (test
# modules import it through _hypothesis_compat, which stubs `given` with
# a skip marker). See requirements-dev.txt for the pinned dev install.
try:
    import hypothesis
except ModuleNotFoundError:
    hypothesis = None

if hypothesis is not None:
    # jit compilation inside hypothesis bodies makes wall-time deadlines noisy
    hypothesis.settings.register_profile(
        "repro", deadline=None, max_examples=25,
        suppress_health_check=[hypothesis.HealthCheck.too_slow],
    )
    hypothesis.settings.load_profile("repro")
