"""Chunked-scan recurrence implementations vs step-by-step oracles.

The RWKV-6 chunked WKV (matmul form, DESIGN.md §3) and the unrolled Mamba
scan must match their naive one-token-at-a-time recurrences exactly —
these oracles are independent of the chunked math, so they catch algebra
errors in the exp-cumsum factorization.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import mamba, rwkv6, transformer


def _wkv_oracle(r, k, v, logw, u, S0):
    """Naive recurrence: o_t = r_t (S_{t-1} + diag(u) k_t v_t^T);
    S_t = diag(w_t) S_{t-1} + k_t v_t^T. Shapes (B,T,H,e), S (B,H,e,e)."""
    B, T, H, e = r.shape
    S = np.asarray(S0, np.float64).copy()
    out = np.zeros((B, T, H, e))
    rn, kn, vn = (np.asarray(t, np.float64) for t in (r, k, v))
    wn = np.exp(np.asarray(logw, np.float64))
    un = np.asarray(u, np.float64)
    for t in range(T):
        for b in range(B):
            for h in range(H):
                kv = np.outer(kn[b, t, h], vn[b, t, h])
                out[b, t, h] = rn[b, t, h] @ (S[b, h] + un[h][:, None] * kv)
                S[b, h] = wn[b, t, h][:, None] * S[b, h] + kv
    return out, S


@pytest.mark.parametrize("T,chunk", [(8, 4), (16, 8), (12, 4)])
def test_wkv_chunked_matches_recurrence(T, chunk):
    B, H, e = 2, 3, 8
    key = jax.random.key(0)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, T, H, e))
    k = jax.random.normal(ks[1], (B, T, H, e))
    v = jax.random.normal(ks[2], (B, T, H, e))
    logw = -jax.random.uniform(ks[3], (B, T, H, e), minval=0.01, maxval=2.0)
    u = jax.random.normal(ks[4], (H, e)) * 0.5
    S0 = jax.random.normal(jax.random.key(9), (B, H, e, e)) * 0.1

    nC = T // chunk
    def c(t):
        return t.reshape(B, nC, chunk, H, e)

    got, S_got = rwkv6._wkv_chunked(c(r), c(k), c(v), c(logw), u, S0)
    want, S_want = _wkv_oracle(r, k, v, logw, u, S0)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(S_got), S_want, atol=1e-4, rtol=1e-4)


def test_rwkv_decode_matches_chunked_forward():
    """Recurrent decode steps reproduce the chunked full-sequence output."""
    cfg = registry.smoke("rwkv6-3b")
    params = transformer.init_params(jax.random.key(0), cfg)
    B, T = 1, 16
    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, T), 0,
                                          cfg.vocab_size)}
    full, _, _ = transformer.forward(params, cfg, batch, mode="train")
    pre = dict(batch, tokens=batch["tokens"][:, :8])
    _, _, cache = transformer.forward(params, cfg, pre, mode="prefill",
                                      max_len=T)
    for t in range(8, T):
        logits, cache = transformer.decode_step(
            params, cfg, batch["tokens"][:, t:t+1], cache, jnp.int32(t), {})
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32), np.asarray(full[:, -1], np.float32),
        atol=5e-2, rtol=5e-2,
    )


def _mamba_oracle(p, cfg, x):
    """One-token-at-a-time mamba forward via the decode path."""
    st = mamba.init_state(cfg, x.shape[0])
    outs = []
    for t in range(x.shape[1]):
        y, st = mamba.mamba_forward(p, cfg, x[:, t:t+1], st)
        outs.append(y)
    return jnp.concatenate(outs, axis=1)


@pytest.mark.parametrize("unroll", [1, 4])
def test_mamba_forward_matches_stepwise(unroll):
    cfg = registry.smoke("jamba-1.5-large-398b")
    cfg = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm,
                                                           scan_unroll=unroll))
    p = mamba.mamba_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model),
                          cfg.jdtype) * 0.1
    full, _ = mamba.mamba_forward(p, cfg, x, None)
    step = _mamba_oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(step, np.float32),
                               atol=2e-2, rtol=2e-2)
