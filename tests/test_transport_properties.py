"""Property tests for ALL transport quantizers (f32 / bf16 / int8 / int4).

Each property is written once as a checker over a concrete (x, transport,
group_size) triple, then driven two ways:

* hypothesis-generated inputs through `tests/_hypothesis_compat` — the
  full strategy sweep when hypothesis is installed, a clean skip when it
  is not;
* seeded numpy fuzz loops that run EVERYWHERE (the hypothesis-absent
  fallback is still a real sweep, not a no-op), across dtype x group-size.

Properties pinned:
  roundtrip   |x - deq(quant(x))| <= scale/2 per element (bf16: 2^-8 rel)
  sign        quantization never flips a sign (to-zero is allowed)
  zero        exact zeros reconstruct to exact zeros
  scale-inv   quant(c*x) == c * quant(x) for powers of two (exactly),
              ~= for general positive c
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import HAS_HYPOTHESIS, hnp, hypothesis, st

from repro import transport
from repro.transport.quantize import CHUNK

GROUP_SIZES = [2, 32, 512, CHUNK]
QUANTIZED = [("int8", 0)] + [("int4", gs) for gs in GROUP_SIZES]
DTYPES = [np.float32, np.float64]  # input dtypes the quantizer must accept


def _quant(x, fmt, gs):
    if fmt == "int4":
        return transport.quantize(x, fmt, group_size=gs)
    return transport.quantize(x, fmt)


def _step(q):
    """Per-element half-quant-step bound implied by the wire's scales."""
    width = q.group_size if q.transport == "int4" else CHUNK
    n = q.n if q.transport == "int4" else q.values.shape[1]
    return 0.5 * np.repeat(np.asarray(q.scales), width, axis=1)[:, :n]


def check_roundtrip_bound(x, fmt, gs=0):
    q = _quant(x, fmt, gs)
    err = np.abs(np.asarray(x, np.float32) -
                 np.asarray(transport.dequantize(q)))
    assert np.all(err <= _step(q) * (1 + 1e-6) + 1e-8), (fmt, gs)


def check_sign_preserved(x, fmt, gs=0):
    deq = np.asarray(transport.roundtrip(x, fmt, group_size=gs or 512))
    xs = np.sign(np.asarray(x, np.float32))
    ds = np.sign(deq)
    assert np.all((ds == xs) | (ds == 0)), (fmt, gs)


def check_zero_preserved(x, fmt, gs=0):
    xz = np.asarray(x, np.float32).copy()
    xz[:, ::3] = 0.0  # plant exact zeros among live values
    deq = np.asarray(transport.roundtrip(jnp.asarray(xz), fmt,
                                         group_size=gs or 512))
    np.testing.assert_array_equal(deq[:, ::3], 0.0)


def check_scale_invariance(x, fmt, gs=0):
    """quant(c*x) ~= c*quant(x): symmetric absmax scales are homogeneous.
    Powers of two rescale the f32 significand exactly, so the identity is
    EXACT there; a generic c only perturbs by float rounding."""
    base = np.asarray(transport.roundtrip(x, fmt, group_size=gs or 512))
    exact = np.asarray(transport.roundtrip(x * 4.0, fmt,
                                           group_size=gs or 512))
    np.testing.assert_array_equal(exact, 4.0 * base)
    c = 3.7
    approx = np.asarray(transport.roundtrip(x * c, fmt,
                                            group_size=gs or 512))
    np.testing.assert_allclose(approx, c * base, rtol=1e-4,
                               atol=1e-5 * (1 + np.abs(base).max()))


CHECKS = [check_roundtrip_bound, check_sign_preserved, check_zero_preserved,
          check_scale_invariance]


# ------------------------------------------------------- seeded fuzz sweep


@pytest.mark.parametrize("check", CHECKS, ids=lambda c: c.__name__)
@pytest.mark.parametrize("fmt,gs", QUANTIZED, ids=str)
def test_fuzz_quantizer_properties(check, fmt, gs):
    """Seeded fuzz: every property x every quantized wire format x varied
    shapes/magnitudes/dtypes — runs with or without hypothesis."""
    seed = {"int8": 1}.get(fmt, gs) * 131 + len(check.__name__)
    rng = np.random.default_rng(seed)
    for _ in range(5):
        k = int(rng.integers(1, 9))
        n = int(rng.integers(1, 2500))
        dtype = DTYPES[int(rng.integers(0, len(DTYPES)))]
        mag = 10.0 ** rng.integers(-6, 7)
        x = jnp.asarray((rng.normal(size=(k, n)) * mag).astype(dtype))
        check(x, fmt, gs)


@pytest.mark.parametrize("fmt,gs", QUANTIZED, ids=str)
def test_fuzz_extreme_values(fmt, gs):
    """Denormal-magnitude and huge-magnitude inputs neither overflow the
    scales nor produce non-finite reconstructions."""
    rng = np.random.default_rng(7)
    for mag in (1e-38, 1e30):
        x = jnp.asarray((rng.normal(size=(2, 300)) * mag).astype(np.float32))
        deq = np.asarray(transport.roundtrip(x, fmt, group_size=gs or 512))
        assert np.all(np.isfinite(deq)), (fmt, gs, mag)
        check_roundtrip_bound(x, fmt, gs)


def test_bf16_relative_error_bound_fuzz():
    """bf16 keeps 8 significand bits: relative error <= 2^-8 everywhere."""
    rng = np.random.default_rng(11)
    for _ in range(8):
        x = jnp.asarray(
            (rng.normal(size=(3, 500)) * 10.0 ** rng.integers(-3, 4))
            .astype(np.float32))
        rt = np.asarray(transport.roundtrip(x, "bf16"))
        np.testing.assert_allclose(rt, np.asarray(x), rtol=2.0**-8)
        check_sign_preserved(x, "bf16")
        check_zero_preserved(x, "bf16")


def test_f32_roundtrip_identity_fuzz():
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.normal(size=(4, 700)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(transport.roundtrip(x, "f32")), np.asarray(x))


# ----------------------------------------------------- hypothesis variants


_ARRAYS = hnp.arrays(
    dtype=np.float32,
    shape=st.tuples(st.integers(1, 6), st.integers(1, 1200)),
    elements=st.floats(-1e6, 1e6, width=32),
)


@hypothesis.given(x=_ARRAYS, fmt_gs=st.sampled_from(QUANTIZED))
def test_hypothesis_roundtrip_bound(x, fmt_gs):
    check_roundtrip_bound(jnp.asarray(x), *fmt_gs)


@hypothesis.given(x=_ARRAYS, fmt_gs=st.sampled_from(QUANTIZED))
def test_hypothesis_sign_and_zero(x, fmt_gs):
    check_sign_preserved(jnp.asarray(x), *fmt_gs)
    check_zero_preserved(jnp.asarray(x), *fmt_gs)


@hypothesis.given(x=_ARRAYS, fmt_gs=st.sampled_from(QUANTIZED))
def test_hypothesis_scale_invariance(x, fmt_gs):
    check_scale_invariance(jnp.asarray(x), *fmt_gs)


def test_hypothesis_status_is_explicit():
    """The module must KNOW whether the @given tests above are live or
    skipped — guards against the compat shim silently eating them."""
    if HAS_HYPOTHESIS:
        import hypothesis as real_hypothesis

        assert hypothesis.given is real_hypothesis.given
    else:
        marker = hypothesis.given()
        assert getattr(marker, "name", "") == "skip" or marker is not None
