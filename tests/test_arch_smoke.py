"""Per-architecture smoke tests: reduced same-family variants (<=2 groups,
d_model<=512, <=4 experts) run one forward/train step and one decode step
on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry, shapes
from repro.models import transformer

ARCHS = sorted(registry.ARCHS)


def _batch(cfg, B=2, T=32, seed=0):
    batch = {
        "tokens": jax.random.randint(jax.random.key(seed), (B, T), 0, cfg.vocab_size)
    }
    if cfg.vision_prefix:
        batch["vision_embeds"] = (
            jax.random.normal(jax.random.key(1), (B, cfg.vision_prefix, cfg.d_model))
            * 0.02
        ).astype(cfg.jdtype)
    if cfg.encoder_layers:
        batch["enc_embeds"] = (
            jax.random.normal(jax.random.key(2), (B, cfg.encoder_len, cfg.d_model))
            * 0.02
        ).astype(cfg.jdtype)
    return batch


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = registry.smoke(name)
            params = transformer.init_params(jax.random.key(0), cfg)
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(arch_setup, name):
    cfg, params = arch_setup(name)
    B, T = 2, 32
    batch = _batch(cfg, B, T)
    logits, aux, off = transformer.forward(params, cfg, batch, mode="train")
    assert logits.shape == (B, T + (cfg.vision_prefix or 0), cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_no_nans(arch_setup, name):
    cfg, params = arch_setup(name)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(transformer.loss_fn)(params, cfg, batch)
    assert jnp.isfinite(loss)
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn) and gn > 0.0


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_consistency(arch_setup, name):
    """Greedy decode after prefill must match the full-sequence forward."""
    cfg, params = arch_setup(name)
    B, T = 2, 32
    batch = _batch(cfg, B, T)
    logits_full, _, off = transformer.forward(params, cfg, batch, mode="train")
    logits_pre, _, cache = transformer.forward(
        params, cfg, batch, mode="prefill", max_len=T + 4
    )
    assert jnp.allclose(
        logits_full[:, -1].astype(jnp.float32),
        logits_pre[:, -1].astype(jnp.float32), atol=2e-2, rtol=2e-2,
    )
    tok = jnp.argmax(logits_pre[:, -1:], axis=-1).astype(jnp.int32)
    extras = {}
    if cfg.rope_style == "mrope":
        extras["positions"] = jnp.full((3, B, 1), T + cfg.vision_prefix, jnp.int32)
    logits_dec, cache2 = transformer.decode_step(
        params, cfg, tok, cache, jnp.int32(T), extras
    )
    assert logits_dec.shape == (B, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits_dec.astype(jnp.float32)))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", ["gemma-2b", "rwkv6-3b", "deepseek-v2-lite-16b",
                                  "jamba-1.5-large-398b"])
def test_decode_matches_forward_teacher_forcing(arch_setup, name):
    """Token-by-token decode reproduces the parallel forward logits."""
    cfg, params = arch_setup(name)
    B, T = 1, 16
    batch = _batch(cfg, B, T)
    logits_full, _, _ = transformer.forward(params, cfg, batch, mode="train")
    pre = 8
    pre_batch = dict(batch, tokens=batch["tokens"][:, :pre])
    _, _, cache = transformer.forward(params, cfg, pre_batch, mode="prefill",
                                      max_len=T)
    for t in range(pre, T):
        tok = batch["tokens"][:, t : t + 1]
        logits_dec, cache = transformer.decode_step(
            params, cfg, tok, cache, jnp.int32(t), {}
        )
    assert jnp.allclose(
        logits_dec[:, 0].astype(jnp.float32),
        logits_full[:, -1].astype(jnp.float32), atol=5e-2, rtol=5e-2,
    ), name


def test_sliding_window_masks_distant_tokens():
    cfg = registry.smoke("gemma-2b", sliding_window=8)
    params = transformer.init_params(jax.random.key(0), cfg)
    b1 = _batch(cfg, 1, 32, seed=3)
    # perturbing a token outside the window must not change the last logit
    toks2 = b1["tokens"].at[0, 0].set((b1["tokens"][0, 0] + 7) % cfg.vocab_size)
    l1, _, _ = transformer.forward(params, cfg, b1, mode="train")
    l2, _, _ = transformer.forward(params, cfg, {"tokens": toks2}, mode="train")
    assert jnp.allclose(l1[0, -1], l2[0, -1], atol=1e-5)
    assert not jnp.allclose(l1[0, 1], l2[0, 1], atol=1e-5)


def test_chunked_attention_matches_naive():
    """q_chunk (the §Perf memory-term optimization) is numerically exact."""
    import dataclasses

    cfg = registry.smoke("starcoder2-15b")
    params = transformer.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, 2, 64)
    l1, _, _ = transformer.forward(params, cfg, batch, mode="train")
    for window in (0, 24):
        c2 = dataclasses.replace(cfg, q_chunk=16, sliding_window=window)
        c1 = dataclasses.replace(cfg, sliding_window=window)
        a, _, _ = transformer.forward(params, c1, batch, mode="train")
        b, _, _ = transformer.forward(params, c2, batch, mode="train")
        assert jnp.allclose(a, b, atol=1e-4, rtol=1e-4)
    del l1


@pytest.mark.parametrize("name", ARCHS)
def test_input_specs_build(name):
    cfg = registry.get(name)
    for sh in shapes.SHAPES.values():
        c2 = shapes.config_for_shape(cfg, sh)
        if sh.kind in ("train", "prefill"):
            specs = shapes.token_batch_specs(c2, 4, 64)
            assert specs["tokens"].shape == (4, 64)
        else:
            d = shapes.decode_specs(c2, 2, 128)
            assert d["token"].shape == (2, 1)
            assert len(jax.tree.leaves(d["cache"])) > 0
