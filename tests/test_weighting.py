"""Property tests for the FedAdp weighting math (paper Eqs. 8-11, Thm. 2)."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import hnp, hypothesis, st

from repro.core import weighting

angles = hnp.arrays(
    np.float64, st.integers(2, 16),
    elements=st.floats(0.0, np.pi, allow_nan=False),
)
sizes = hnp.arrays(
    np.float64, st.integers(2, 16),
    elements=st.floats(1.0, 1e4, allow_nan=False),
)


@hypothesis.given(angles)
def test_gompertz_monotone_decreasing_and_bounded(theta):
    th = np.sort(theta)
    f = np.asarray(weighting.gompertz(jnp.asarray(th)))
    assert np.all(np.diff(f) <= 1e-6), "f must be non-increasing in theta"
    assert np.all(f >= 0.0) and np.all(f <= weighting.DEFAULT_ALPHA + 1e-6)


@hypothesis.given(st.data())
def test_weights_form_simplex(data):
    k = data.draw(st.integers(2, 16))
    th = data.draw(hnp.arrays(np.float64, k, elements=st.floats(0, np.pi)))
    d = data.draw(hnp.arrays(np.float64, k, elements=st.floats(1, 1e4)))
    w = np.asarray(weighting.fedadp_weights(jnp.asarray(th), jnp.asarray(d)))
    assert np.all(w >= 0)
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-5)


@hypothesis.given(st.data())
def test_equal_angles_reduce_to_fedavg(data):
    """Eq. 11: when all smoothed angles are equal, FedAdp == FedAvg."""
    k = data.draw(st.integers(2, 12))
    th = data.draw(st.floats(0.0, np.pi))
    d = data.draw(hnp.arrays(np.float64, k, elements=st.floats(1, 1e4)))
    w_adp = np.asarray(
        weighting.fedadp_weights(jnp.full((k,), th), jnp.asarray(d))
    )
    w_avg = np.asarray(weighting.fedavg_weights(jnp.asarray(d)))
    np.testing.assert_allclose(w_adp, w_avg, rtol=1e-5)


@hypothesis.given(st.data())
def test_theorem2_expected_contribution(data):
    """Thm. 2: FedAdp's E_{i|t}[cos theta_i] >= FedAvg's (equal data sizes).

    Both weight orders follow the contribution order, so Chebyshev's sum
    inequality applies; we check it numerically over random angle sets.
    """
    k = data.draw(st.integers(2, 16))
    th = data.draw(
        hnp.arrays(np.float64, k, elements=st.floats(0.0, np.pi * 0.999))
    )
    d = jnp.ones((k,))
    th_j = jnp.asarray(th)
    cos = jnp.cos(th_j)
    e_adp = weighting.expected_contribution(
        weighting.fedadp_weights(th_j, d), cos
    )
    e_avg = weighting.expected_contribution(weighting.fedavg_weights(d), cos)
    assert float(e_adp) >= float(e_avg) - 1e-6


def test_weights_ordering_tracks_contribution():
    th = jnp.asarray([0.2, 0.8, 1.4])  # better -> worse
    w = np.asarray(weighting.fedadp_weights(th, jnp.ones(3)))
    assert w[0] > w[1] > w[2]


def test_smoothed_angle_running_mean():
    st_ = weighting.AngleState.init(3)
    sel = jnp.asarray([True, True, False])
    st_ = weighting.update_smoothed_angle(st_, jnp.asarray([1.0, 2.0, 9.0]), sel)
    np.testing.assert_allclose(st_.smoothed, [1.0, 2.0, 0.0])
    st_ = weighting.update_smoothed_angle(st_, jnp.asarray([3.0, 0.0, 9.0]),
                                          jnp.asarray([True, False, False]))
    np.testing.assert_allclose(st_.smoothed, [2.0, 2.0, 0.0])  # (1+3)/2
    assert st_.count.tolist() == [2, 1, 0]


def test_angle_from_stats_matches_arccos():
    a = np.random.default_rng(0).normal(size=128)
    b = np.random.default_rng(1).normal(size=128)
    th = weighting.instantaneous_angle(
        jnp.dot(a, b), jnp.dot(a, a), jnp.dot(b, b)
    )
    want = np.arccos(np.dot(a, b) / (np.linalg.norm(a) * np.linalg.norm(b)))
    np.testing.assert_allclose(float(th), want, rtol=1e-5)


def test_gompertz_alpha_amplifies_separation():
    th = jnp.asarray([0.3, 1.2])
    gaps = [
        float(weighting.gompertz(th, alpha)[0] - weighting.gompertz(th, alpha)[1])
        for alpha in (2.0, 5.0)
    ]
    assert gaps[1] > gaps[0]
