"""Golden convergence regression — the paper's Table-I claim as a test.

tests/golden/convergence.json pins fixed-seed rounds-to-85% for fedadp vs
fedavg on the 5 IID + 5 one-class synthetic task across EVERY (uplink,
downlink) wire pair (scripts/gen_golden_convergence.py regenerates it).
Three layers of pinning:

* the committed file itself must satisfy the paper's claims (fedadp <=
  fedavg per pair; every wire within 10% of the f32/f32 reference) — a
  regenerated golden that violates them cannot be committed green;
* a re-run subset must reproduce the golden counts within the same 10%
  bound (catching silent convergence regressions, not just file edits);
* an 8-host-device subprocess re-runs the fully-compressed pair
  (int4 uplink + int8 downlink) through engine="flat_sharded", so the
  sharded engine's convergence — not merely its one-round numerics — is
  pinned under the bidirectional quantized wire.
"""
import json
import os
import subprocess
import sys

import pytest

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "convergence.json")


def _golden():
    with open(GOLDEN) as f:
        return json.load(f)


def _ratio_ok(rounds, reference, bound=1.1):
    return (rounds is not None and reference is not None
            and rounds <= bound * reference)


def test_golden_file_exists_and_is_complete():
    g = _golden()
    from repro import transport

    want = {f"{m}/{u}/{d}"
            for m in ("fedadp", "fedavg")
            for u in transport.TRANSPORTS
            for d in transport.DOWNLINKS}
    assert set(g["entries"]) == want
    # every wire pair REACHED the target inside the budget — a null here
    # means compression broke convergence outright
    assert all(isinstance(v, int) for v in g["entries"].values()), g["entries"]


def test_golden_fedadp_beats_fedavg_per_wire_pair():
    """Table I, per transport: adaptive weighting must reduce rounds under
    every wire pair, compressed or not."""
    e = _golden()["entries"]
    for key, rounds in e.items():
        if not key.startswith("fedadp/"):
            continue
        avg = e["fedavg/" + key.split("/", 1)[1]]
        assert rounds <= avg, (key, rounds, avg)


def test_golden_transport_ratio_within_10pct():
    """Compression must not cost rounds: every (uplink, downlink) pair
    stays within 1.1x of that method's f32/f32 reference — int4 and the
    quantized downlinks included (the acceptance bound)."""
    e = _golden()["entries"]
    for method in ("fedadp", "fedavg"):
        ref = e[f"{method}/f32/f32"]
        for key, rounds in e.items():
            if key.startswith(method + "/"):
                assert _ratio_ok(rounds, ref), (key, rounds, ref)


# the re-run subset: the reference, the fully-compressed fedadp pair, an
# intermediate pair, and the slowest fedavg wire (the 1.1-bound extreme)
REPRO_CASES = [
    ("fedadp", "f32", "f32"),
    ("fedadp", "int4", "int8"),
    ("fedadp", "int8", "bf16"),
    ("fedavg", "int4", "int8"),
]


@pytest.mark.parametrize("method,uplink,downlink", REPRO_CASES)
def test_golden_reproduces(method, uplink, downlink):
    """Recomputed rounds-to-target must match the golden within the 10%
    acceptance band in BOTH directions (neither regressed nor silently
    shifted) — same task inputs, fixed seed."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.common import node_spec, run_fl

    g = _golden()
    task = g["task"]
    hist, _ = run_fl(
        method, node_spec(5, 5, 1), rounds=task["max_rounds"],
        target=task["target"], engine=task["engine"], transport=uplink,
        downlink=downlink, group_size=task["group_size"],
        seed=task["seed"], eval_every=task["eval_every"],
    )
    golden = g["entries"][f"{method}/{uplink}/{downlink}"]
    got = hist.rounds_to_target
    assert _ratio_ok(got, golden) and _ratio_ok(golden, got), (got, golden)


def test_golden_delta_section_complete_and_claims_hold():
    """The subset-selection delta-downlink section: every delta wire pair
    plus the per-method f32/f32 reference is present and reached the
    target; fedadp <= fedavg per wire; every delta wire within 1.1x of
    that method's plain-broadcast reference under the SAME 5-of-10
    selection (delta encoding must not cost rounds)."""
    d = _golden()["delta"]
    wires = [tuple(w) for w in d["wires"]]
    want = {f"{m}/{u}/{dn}"
            for m in ("fedadp", "fedavg")
            for u, dn in [("f32", "f32")] + wires}
    assert set(d["entries"]) == want
    assert all(isinstance(v, int) for v in d["entries"].values()), d["entries"]
    assert d["task"]["clients_per_round"] < 10  # genuinely partial
    for u, dn in wires:
        assert d["entries"][f"fedadp/{u}/{dn}"] <= d["entries"][f"fedavg/{u}/{dn}"]
    for method in ("fedadp", "fedavg"):
        ref = d["entries"][f"{method}/f32/f32"]
        for u, dn in wires:
            rounds = d["entries"][f"{method}/{u}/{dn}"]
            assert _ratio_ok(rounds, ref), (method, u, dn, rounds, ref)


@pytest.mark.parametrize("method,uplink,downlink,delta", [
    ("fedadp", "f32", "f32", False),   # the subset-selection reference
    ("fedadp", "int4", "int8", True),  # fully-compressed delta wire
    ("fedavg", "f32", "int8", True),   # slow-method delta wire
])
def test_golden_delta_reproduces(method, uplink, downlink, delta):
    """Recomputed subset-selection rounds-to-target must match the delta
    golden within the 10% band in both directions — this re-runs the
    per-client broadcast-state path (ring + versions + byte split) end
    to end on every CI leg."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.common import node_spec, run_fl

    d = _golden()["delta"]
    task = d["task"]
    hist, _ = run_fl(
        method, node_spec(5, 5, 1), rounds=task["max_rounds"],
        target=task["target"], engine=task["engine"], transport=uplink,
        downlink=downlink, downlink_delta=delta,
        downlink_ring=task["downlink_ring"],
        group_size=task["group_size"], seed=task["seed"],
        eval_every=task["eval_every"],
        clients_per_round=task["clients_per_round"],
    )
    golden = d["entries"][f"{method}/{uplink}/{downlink}"]
    got = hist.rounds_to_target
    assert _ratio_ok(got, golden) and _ratio_ok(golden, got), (got, golden)


def test_golden_sharded_subprocess_quantized_both_directions():
    """engine="flat_sharded" on an 8-way host-device mesh must converge in
    the same rounds as the golden for the fully-compressed wire (int4
    uplink + int8 downlink) — K=10 clients pad to 16 rows over 8 shards,
    so the padded-row/zero-weight path runs every round of a REAL
    convergence trajectory, not just a one-round parity check."""
    g = _golden()
    golden = g["entries"]["fedadp/int4/int8"]
    task = g["task"]
    prog = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from benchmarks.common import node_spec, run_fl
mesh = jax.make_mesh((8,), ("data",))
hist, _ = run_fl(
    "fedadp", node_spec(5, 5, 1), rounds={task["max_rounds"]},
    target={task["target"]}, engine="flat_sharded", transport="int4",
    downlink="int8", group_size={task["group_size"]}, seed={task["seed"]},
    eval_every={task["eval_every"]}, mesh=mesh)
print("ROUNDS_TO_TARGET", hist.rounds_to_target)
"""
    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "ROUNDS_TO_TARGET" in out.stdout, out.stderr[-2000:]
    got = out.stdout.split("ROUNDS_TO_TARGET", 1)[1].split()[0]
    got = None if got == "None" else int(got)
    assert _ratio_ok(got, golden) and _ratio_ok(golden, got), (got, golden)
