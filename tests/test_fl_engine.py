"""FL round-engine tests: parallel/sequential equivalence, FedAvg baseline
semantics, stale-angle variant, and the paper's Fig.2 angle-separation
phenomenon on a tiny task."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fl, treemath, weighting
from repro.models import small


def _toy_problem(K=4, tau=3, B=8, d=12, seed=0):
    """Linear regression clients with heterogeneous targets."""
    rng = np.random.default_rng(seed)
    params = {"w": jnp.zeros((d, 1), jnp.float32), "b": jnp.zeros((1,), jnp.float32)}
    X = rng.normal(size=(K, tau, B, d)).astype(np.float32)
    w_true = rng.normal(size=(K, d, 1)).astype(np.float32)  # non-IID targets
    Y = np.einsum("ktbd,kde->ktbe", X, w_true)

    def loss_fn(p, batch):
        x, y = batch
        pred = x @ p["w"] + p["b"]
        return jnp.mean((pred - y) ** 2)

    return params, loss_fn, (jnp.asarray(X), jnp.asarray(Y))


def _run(mode, method, stale=False, seed=0, rounds=3):
    params, loss_fn, batches = _toy_problem(seed=seed)
    K = batches[0].shape[0]
    cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                      method=method, mode=mode, stale_angles=stale,
                      base_lr=0.05)
    rf = jax.jit(fl.make_round_fn(loss_fn, cfg))
    st = fl.init_round_state(cfg, params)
    sel = jnp.arange(K, dtype=jnp.int32)
    sizes = jnp.asarray([10.0, 20.0, 30.0, 40.0])
    ms = []
    for r in range(rounds):
        st, m = rf(st, batches, sel, sizes)
        ms.append(m)
    return st.params, st.angle, ms


@pytest.mark.parametrize("method", ["fedadp", "fedavg"])
def test_parallel_sequential_equivalence(method):
    """The two engines implement identical math (modulo accumulation order)."""
    p1, s1, m1 = _run("parallel", method)
    p2, s2, m2 = _run("sequential", method)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                rtol=2e-4, atol=2e-6),
        p1, p2,
    )
    np.testing.assert_allclose(s1.smoothed, s2.smoothed, rtol=2e-4)
    np.testing.assert_allclose(m1[-1]["theta"], m2[-1]["theta"], rtol=2e-4)
    np.testing.assert_allclose(m1[-1]["weights"], m2[-1]["weights"], rtol=2e-4)


def test_fedavg_weights_are_data_proportional():
    _, _, ms = _run("parallel", "fedavg")
    np.testing.assert_allclose(ms[0]["weights"], [0.1, 0.2, 0.3, 0.4], rtol=1e-6)


def test_fedavg_round_is_weighted_average_of_deltas():
    params, loss_fn, batches = _toy_problem()
    K = 4
    cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                      method="fedavg", base_lr=0.05)
    rf = fl.make_round_fn(loss_fn, cfg)
    sizes = jnp.ones((K,))
    st, _ = rf(fl.init_round_state(cfg, params), batches,
               jnp.arange(K, dtype=jnp.int32), sizes)
    new_params = st.params
    # manual: average the per-client local_update deltas
    deltas = [
        fl.local_update(loss_fn, params,
                        jax.tree.map(lambda x: x[k], batches), 0.05)[0]
        for k in range(K)
    ]
    manual = jax.tree.map(
        lambda p, *ds: p + sum(d.astype(jnp.float32) for d in ds) / K,
        params, *deltas,
    )
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6), new_params, manual)


def test_stale_angles_runs_and_converges_to_exact():
    """After a warmup round the stale reference is the previous delta; the
    variant must stay finite and produce simplex weights."""
    p, s, ms = _run("sequential", "fedadp", stale=True, rounds=4)
    for m in ms:
        w = np.asarray(m["weights"])
        assert np.all(np.isfinite(w)) and abs(w.sum() - 1) < 1e-5
    assert np.all(np.isfinite(np.asarray(jax.tree.leaves(p)[0])))


def test_fedadp_upweights_aligned_client():
    """A client whose gradient opposes the global direction must get less
    weight under FedAdp than under FedAvg."""
    _, _, ms = _run("parallel", "fedadp", rounds=5)
    th = np.asarray(ms[-1]["theta_smoothed"])
    w = np.asarray(ms[-1]["weights"])
    assert w[np.argmin(th)] >= w[np.argmax(th)]


def test_angle_separates_skew_fig2():
    """Paper Fig. 2: highly skewed (1-class) nodes drift to larger smoothed
    angles than IID nodes."""
    from repro.core.server import FedServer
    from repro.data import synthetic

    train, test = synthetic.make_image_task(seed=0, num_train=4000, num_test=500)
    nodes = synthetic.make_federated(
        train, [("iid", None)] * 3 + [("xclass", 1)] * 3,
        samples_per_node=200, seed=1,
    )
    cfg = fl.FLConfig(num_clients=6, clients_per_round=6, local_steps=4,
                      method="fedadp", base_lr=0.05)
    server = FedServer("mlr", cfg, nodes, test, batch_size=50, seed=0)
    hist = server.run(rounds=10)
    th = hist.thetas[-1]
    assert np.mean(th[3:]) > np.mean(th[:3]), (
        f"non-IID angles {th[3:]} should exceed IID angles {th[:3]}"
    )


def test_fedprox_proximal_term_shrinks_deltas():
    """FedProx baseline: the proximal term pulls local updates toward the
    global model, so deltas shrink as mu grows."""
    params, loss_fn, batches = _toy_problem()
    import repro.core.treemath as tm

    norms = []
    for mu in (0.0, 10.0):
        d, _ = fl.local_update(loss_fn, params,
                               jax.tree.map(lambda x: x[0], batches), 0.05,
                               prox_mu=mu)
        norms.append(float(tm.global_norm(d)))
    assert norms[1] < norms[0]


def test_dense_only_angle_mask_changes_stats_not_update():
    """The MoE angle filter alters angle statistics only; with fedavg
    weighting the aggregated model must be identical."""
    from repro.configs import registry
    from repro.data import synthetic
    from repro.models import transformer

    cfg = registry.smoke("deepseek-v2-lite-16b")
    params = transformer.init_params(jax.random.key(0), cfg)
    K, tau, B, T = 2, 1, 2, 32
    toks = synthetic.lm_token_batches(0, K, tau * B, T, cfg.vocab_size)
    batches = {"tokens": jnp.asarray(toks.reshape(K, tau, B, T))}
    outs = {}
    for name, pred in (("all", None), ("dense", fl.moe_dense_only_pred)):
        flcfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=tau,
                            method="fedavg")
        rf = jax.jit(fl.make_round_fn(
            lambda p, b: transformer.loss_fn(p, cfg, b), flcfg, angle_pred=pred))
        outs[name] = rf(fl.init_round_state(flcfg, params), batches,
                        jnp.arange(K, dtype=jnp.int32), jnp.ones((K,)))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32)),
        outs["all"][0].params, outs["dense"][0].params)
    assert not np.allclose(outs["all"][1]["theta"], outs["dense"][1]["theta"])


def test_selection_subset_updates_only_selected_slots():
    params, loss_fn, batches = _toy_problem()
    K = 4
    cfg = fl.FLConfig(num_clients=8, clients_per_round=K, local_steps=3,
                      method="fedadp", base_lr=0.05)
    rf = fl.make_round_fn(loss_fn, cfg)
    sel = jnp.asarray([1, 3, 5, 7], jnp.int32)
    st, _ = rf(fl.init_round_state(cfg, params), batches, sel,
               jnp.ones((K,)))
    state = st.angle
    assert state.count.tolist() == [0, 1, 0, 1, 0, 1, 0, 1]
    assert np.all(np.asarray(state.smoothed[jnp.asarray([0, 2, 4, 6])]) == 0)
