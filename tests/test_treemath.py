"""treemath vs numpy ground truth, incl. hypothesis property checks."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import treemath


def _np_flat(tree):
    return np.concatenate([np.asarray(x, np.float64).ravel()
                           for x in jax.tree.leaves(tree)])


def _rand_tree(seed, dtype=jnp.float32):
    k = jax.random.split(jax.random.key(seed), 3)
    return {"x": jax.random.normal(k[0], (37, 11), dtype),
            "y": [jax.random.normal(k[1], (5,), dtype),
                  jax.random.normal(k[2], (2, 3, 4), dtype)]}


def test_dot_and_norms():
    a, b = _rand_tree(0), _rand_tree(1)
    d, na, nb = treemath.tree_dot_and_norms(a, b)
    fa, fb = _np_flat(a), _np_flat(b)
    np.testing.assert_allclose(float(d), fa @ fb, rtol=1e-5)
    np.testing.assert_allclose(float(na), fa @ fa, rtol=1e-5)
    np.testing.assert_allclose(float(nb), fb @ fb, rtol=1e-5)
    np.testing.assert_allclose(float(treemath.tree_dot(a, b)), fa @ fb, rtol=1e-5)
    np.testing.assert_allclose(float(treemath.tree_sqnorm(a)), fa @ fa, rtol=1e-5)


def test_batched_ops():
    stacked = jax.tree.map(lambda *x: jnp.stack(x),
                           *[_rand_tree(i) for i in range(4)])
    single = _rand_tree(7)
    dots = np.asarray(treemath.tree_vdot_batched(stacked, single))
    sqs = np.asarray(treemath.tree_sqnorm_batched(stacked))
    fs = _np_flat(single)
    for k in range(4):
        fk = _np_flat(_rand_tree(k))
        np.testing.assert_allclose(dots[k], fk @ fs, rtol=1e-5)
        np.testing.assert_allclose(sqs[k], fk @ fk, rtol=1e-5)


@hypothesis.given(st.lists(st.floats(-2, 2), min_size=2, max_size=6))
def test_weighted_sum_linear(ws):
    stacked = jax.tree.map(lambda *x: jnp.stack(x),
                           *[_rand_tree(i) for i in range(len(ws))])
    w = jnp.asarray(ws, jnp.float32)
    got = treemath.tree_weighted_sum(stacked, w)
    want = jax.tree.map(
        lambda s: jnp.tensordot(w, s.astype(jnp.float32), axes=1), stacked
    )
    jax.tree.map(lambda g, x: np.testing.assert_allclose(
        np.asarray(g), np.asarray(x), rtol=1e-4, atol=1e-5), got, want)


def test_axpy_and_add_sub():
    a, b = _rand_tree(0), _rand_tree(1)
    got = treemath.tree_axpy(2.5, a, b)
    np.testing.assert_allclose(_np_flat(got), 2.5 * _np_flat(a) + _np_flat(b),
                               rtol=1e-5)
    np.testing.assert_allclose(_np_flat(treemath.tree_sub(
        treemath.tree_add(a, b), b)), _np_flat(a), rtol=1e-5, atol=1e-6)


def test_bf16_accumulates_in_f32():
    # 4096 bf16 ones: naive bf16 accumulation saturates at 256
    t = {"x": jnp.ones((4096,), jnp.bfloat16)}
    assert float(treemath.tree_sqnorm(t)) == 4096.0
