"""treemath vs numpy ground truth, incl. hypothesis property checks."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import hypothesis, st

from repro.core import treemath


def _np_flat(tree):
    return np.concatenate([np.asarray(x, np.float64).ravel()
                           for x in jax.tree.leaves(tree)])


def _rand_tree(seed, dtype=jnp.float32):
    k = jax.random.split(jax.random.key(seed), 3)
    return {"x": jax.random.normal(k[0], (37, 11), dtype),
            "y": [jax.random.normal(k[1], (5,), dtype),
                  jax.random.normal(k[2], (2, 3, 4), dtype)]}


def test_dot_and_norms():
    a, b = _rand_tree(0), _rand_tree(1)
    d, na, nb = treemath.tree_dot_and_norms(a, b)
    fa, fb = _np_flat(a), _np_flat(b)
    np.testing.assert_allclose(float(d), fa @ fb, rtol=1e-5)
    np.testing.assert_allclose(float(na), fa @ fa, rtol=1e-5)
    np.testing.assert_allclose(float(nb), fb @ fb, rtol=1e-5)
    np.testing.assert_allclose(float(treemath.tree_dot(a, b)), fa @ fb, rtol=1e-5)
    np.testing.assert_allclose(float(treemath.tree_sqnorm(a)), fa @ fa, rtol=1e-5)


def test_batched_ops():
    stacked = jax.tree.map(lambda *x: jnp.stack(x),
                           *[_rand_tree(i) for i in range(4)])
    single = _rand_tree(7)
    dots = np.asarray(treemath.tree_vdot_batched(stacked, single))
    sqs = np.asarray(treemath.tree_sqnorm_batched(stacked))
    fs = _np_flat(single)
    for k in range(4):
        fk = _np_flat(_rand_tree(k))
        np.testing.assert_allclose(dots[k], fk @ fs, rtol=1e-5)
        np.testing.assert_allclose(sqs[k], fk @ fk, rtol=1e-5)


@hypothesis.given(st.lists(st.floats(-2, 2), min_size=2, max_size=6))
def test_weighted_sum_linear(ws):
    stacked = jax.tree.map(lambda *x: jnp.stack(x),
                           *[_rand_tree(i) for i in range(len(ws))])
    w = jnp.asarray(ws, jnp.float32)
    got = treemath.tree_weighted_sum(stacked, w)
    want = jax.tree.map(
        lambda s: jnp.tensordot(w, s.astype(jnp.float32), axes=1), stacked
    )
    jax.tree.map(lambda g, x: np.testing.assert_allclose(
        np.asarray(g), np.asarray(x), rtol=1e-4, atol=1e-5), got, want)


def test_axpy_and_add_sub():
    a, b = _rand_tree(0), _rand_tree(1)
    got = treemath.tree_axpy(2.5, a, b)
    np.testing.assert_allclose(_np_flat(got), 2.5 * _np_flat(a) + _np_flat(b),
                               rtol=1e-5)
    np.testing.assert_allclose(_np_flat(treemath.tree_sub(
        treemath.tree_add(a, b), b)), _np_flat(a), rtol=1e-5, atol=1e-6)


def test_bf16_accumulates_in_f32():
    # 4096 bf16 ones: naive bf16 accumulation saturates at 256
    t = {"x": jnp.ones((4096,), jnp.bfloat16)}
    assert float(treemath.tree_sqnorm(t)) == 4096.0


def test_tree_ravel_round_trip():
    t = _rand_tree(0)
    vec, unravel = treemath.tree_ravel(t)
    assert vec.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(vec), _np_flat(t).astype(np.float32))
    back = unravel(vec)
    assert jax.tree.structure(back) == jax.tree.structure(t)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), back, t)


def test_tree_ravel_round_trip_preserves_dtype():
    t = _rand_tree(3, jnp.bfloat16)
    vec, unravel = treemath.tree_ravel(t)
    back = unravel(vec)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(back))


def test_tree_ravel_stacked_matches_per_client_ravel():
    trees = [_rand_tree(i) for i in range(3)]
    stacked = jax.tree.map(lambda *x: jnp.stack(x), *trees)
    buf, unravel = treemath.tree_ravel_stacked(stacked)
    assert buf.shape[0] == 3 and buf.dtype == jnp.float32
    for k, t in enumerate(trees):
        np.testing.assert_allclose(np.asarray(buf[k]),
                                   _np_flat(t).astype(np.float32))
    # unravel maps an (N,) row back to the UNSTACKED structure
    back = unravel(buf[1])
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6), back, trees[1])


def test_ravel_consistent_with_tree_reductions():
    a, b = _rand_tree(0), _rand_tree(1)
    va, _ = treemath.tree_ravel(a)
    vb, _ = treemath.tree_ravel(b)
    np.testing.assert_allclose(float(jnp.dot(va, vb)),
                               float(treemath.tree_dot(a, b)), rtol=1e-5)
    np.testing.assert_allclose(float(jnp.dot(va, va)),
                               float(treemath.tree_sqnorm(a)), rtol=1e-5)


def test_unravel_cache_reused():
    t = _rand_tree(0)
    _, u1 = treemath.tree_ravel(t)
    _, u2 = treemath.tree_ravel(_rand_tree(5))  # same structure/shapes/dtypes
    assert u1 is u2
    _, u3 = treemath.tree_ravel({"z": jnp.zeros((3,))})
    assert u3 is not u1


def test_segment_mask_alignment():
    t = _rand_tree(0)
    keep = [True, False, True]  # flatten order: x, y[0], y[1]
    m = np.asarray(treemath.segment_mask(t, keep))
    sizes = [x.size for x in jax.tree.leaves(t)]
    assert m.shape == (sum(sizes),)
    np.testing.assert_array_equal(m[: sizes[0]], 1.0)
    np.testing.assert_array_equal(m[sizes[0]: sizes[0] + sizes[1]], 0.0)
    np.testing.assert_array_equal(m[sizes[0] + sizes[1]:], 1.0)


# ---------------------------------------------------------------------------
# Blocked (client x model) layout — the 2D flat engine's shard-local ravel.
# ---------------------------------------------------------------------------

from jax.sharding import PartitionSpec as P  # noqa: E402


def _blocked_fixture(k=3, m=4):
    rng = np.random.default_rng(0)
    stacked = {
        "wq": jnp.asarray(rng.normal(size=(k, 6, 8)).astype(np.float32)),
        "w_down": jnp.asarray(rng.normal(size=(k, 8, 5)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(k, 7)).astype(np.float32)),
        "s": jnp.asarray(rng.normal(size=(k,)).astype(np.float32)),
    }
    pspecs = {"wq": P(None, "model"), "w_down": P("model", None),
              "b": P(None), "s": P()}
    return stacked, pspecs


def test_blocked_layout_widths():
    stacked, pspecs = _blocked_fixture()
    lay = treemath.blocked_layout(stacked, pspecs, 4)
    # flatten order: b, s, w_down, wq
    # b (7,) replicated -> ceil(7/4)=2; s () -> ceil(1/4)=1;
    # w_down (8,5) model on dim 0 -> 40/4=10; wq (6,8) model on dim 1 -> 12
    assert lay.widths == (2, 1, 10, 12)
    assert lay.width == 25
    assert lay.n_logical == 7 + 1 + 40 + 48
    assert lay.sharded_dims == (-1, -1, 0, 1)


def test_blocked_layout_rejects_nondivisible_sharded_dim():
    stacked, pspecs = _blocked_fixture()
    try:
        treemath.blocked_layout(stacked, pspecs, 3)  # wq dim 1 = 8, 8 % 3
    except ValueError as e:
        assert "divisible" in str(e)
    else:
        raise AssertionError("expected ValueError")


def test_blocked_ravel_split_inverse():
    """Concatenating every shard's blocked ravel recovers each leaf exactly
    (sharded leaves from their local blocks, replicated leaves from the
    column slices), so the blocked order is a permutation of the global
    ravel — nothing lost, nothing duplicated."""
    m = 4
    stacked, pspecs = _blocked_fixture(m=m)
    lay = treemath.blocked_layout(stacked, pspecs, m)
    leaves = jax.tree.leaves(stacked)
    k = leaves[0].shape[0]
    blocks = []
    for j in range(m):
        loc = []
        for x, sdim in zip(leaves, lay.sharded_dims):
            if sdim >= 0:
                step = x.shape[sdim + 1] // m
                sl = [slice(None)] * x.ndim
                sl[sdim + 1] = slice(j * step, (j + 1) * step)
                loc.append(x[tuple(sl)])
            else:
                loc.append(x)
        blk = treemath.blocked_ravel_local(loc, lay, j)
        assert blk.shape == (k, lay.width)
        blocks.append(blk)
    # reassemble per leaf and compare
    for i, (x, shape, sdim) in enumerate(
            zip(leaves, lay.shapes, lay.sharded_dims)):
        segs = [treemath.blocked_split(b, lay)[i] for b in blocks]
        if sdim >= 0:
            step = shape[sdim] // m
            local = list(shape)
            local[sdim] = step
            parts = [s.reshape((k,) + tuple(local)) for s in segs]
            rec = jnp.concatenate(parts, axis=sdim + 1)
        else:
            size = int(np.prod(shape)) if shape else 1
            rec = jnp.concatenate(segs, axis=1)[:, :size].reshape(
                (k,) + shape)
        np.testing.assert_array_equal(np.asarray(rec), np.asarray(x))


def test_blocked_ravel_pads_replicated_tail_with_zeros():
    m = 4
    stacked, pspecs = _blocked_fixture(m=m)
    lay = treemath.blocked_layout(stacked, pspecs, m)
    leaves = jax.tree.leaves(stacked)
    # last shard's replicated segments carry the ceil-split zero padding:
    # b is leaf 0 (width 2, 7 elements -> shard 3 holds [b[6], 0])
    loc = []
    for x, sdim in zip(leaves, lay.sharded_dims):
        if sdim >= 0:
            step = x.shape[sdim + 1] // m
            sl = [slice(None)] * x.ndim
            sl[sdim + 1] = slice(3 * step, 4 * step)
            loc.append(x[tuple(sl)])
        else:
            loc.append(x)
    blk = treemath.blocked_ravel_local(loc, lay, 3)
    seg_b = np.asarray(treemath.blocked_split(blk, lay)[0])
    np.testing.assert_array_equal(seg_b[:, 0], np.asarray(leaves[0])[:, 6])
    np.testing.assert_array_equal(seg_b[:, 1], 0.0)


def test_blocked_segment_mask_offsets_and_keep():
    stacked, pspecs = _blocked_fixture()
    lay = treemath.blocked_layout(stacked, pspecs, 4)
    # flatten order: b, s, w_down, wq — drop w_down
    mask = np.asarray(treemath.blocked_segment_mask(
        lay, [True, True, False, True]))
    assert mask.shape == (lay.width,)
    off = 0
    for w, keep in zip(lay.widths, (1.0, 1.0, 0.0, 1.0)):
        np.testing.assert_array_equal(mask[off:off + w], keep)
        off += w


def test_blocked_layout_rejects_mixed_axis_spec():
    stacked, _ = _blocked_fixture()
    pspecs = {"wq": P(None, ("data", "model")), "w_down": P("model", None),
              "b": P(None), "s": P()}
    try:
        treemath.blocked_layout(stacked, pspecs, 4)
    except ValueError as e:
        assert "mixes" in str(e)
    else:
        raise AssertionError("expected ValueError")
