"""treemath vs numpy ground truth, incl. hypothesis property checks."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import hypothesis, st

from repro.core import treemath


def _np_flat(tree):
    return np.concatenate([np.asarray(x, np.float64).ravel()
                           for x in jax.tree.leaves(tree)])


def _rand_tree(seed, dtype=jnp.float32):
    k = jax.random.split(jax.random.key(seed), 3)
    return {"x": jax.random.normal(k[0], (37, 11), dtype),
            "y": [jax.random.normal(k[1], (5,), dtype),
                  jax.random.normal(k[2], (2, 3, 4), dtype)]}


def test_dot_and_norms():
    a, b = _rand_tree(0), _rand_tree(1)
    d, na, nb = treemath.tree_dot_and_norms(a, b)
    fa, fb = _np_flat(a), _np_flat(b)
    np.testing.assert_allclose(float(d), fa @ fb, rtol=1e-5)
    np.testing.assert_allclose(float(na), fa @ fa, rtol=1e-5)
    np.testing.assert_allclose(float(nb), fb @ fb, rtol=1e-5)
    np.testing.assert_allclose(float(treemath.tree_dot(a, b)), fa @ fb, rtol=1e-5)
    np.testing.assert_allclose(float(treemath.tree_sqnorm(a)), fa @ fa, rtol=1e-5)


def test_batched_ops():
    stacked = jax.tree.map(lambda *x: jnp.stack(x),
                           *[_rand_tree(i) for i in range(4)])
    single = _rand_tree(7)
    dots = np.asarray(treemath.tree_vdot_batched(stacked, single))
    sqs = np.asarray(treemath.tree_sqnorm_batched(stacked))
    fs = _np_flat(single)
    for k in range(4):
        fk = _np_flat(_rand_tree(k))
        np.testing.assert_allclose(dots[k], fk @ fs, rtol=1e-5)
        np.testing.assert_allclose(sqs[k], fk @ fk, rtol=1e-5)


@hypothesis.given(st.lists(st.floats(-2, 2), min_size=2, max_size=6))
def test_weighted_sum_linear(ws):
    stacked = jax.tree.map(lambda *x: jnp.stack(x),
                           *[_rand_tree(i) for i in range(len(ws))])
    w = jnp.asarray(ws, jnp.float32)
    got = treemath.tree_weighted_sum(stacked, w)
    want = jax.tree.map(
        lambda s: jnp.tensordot(w, s.astype(jnp.float32), axes=1), stacked
    )
    jax.tree.map(lambda g, x: np.testing.assert_allclose(
        np.asarray(g), np.asarray(x), rtol=1e-4, atol=1e-5), got, want)


def test_axpy_and_add_sub():
    a, b = _rand_tree(0), _rand_tree(1)
    got = treemath.tree_axpy(2.5, a, b)
    np.testing.assert_allclose(_np_flat(got), 2.5 * _np_flat(a) + _np_flat(b),
                               rtol=1e-5)
    np.testing.assert_allclose(_np_flat(treemath.tree_sub(
        treemath.tree_add(a, b), b)), _np_flat(a), rtol=1e-5, atol=1e-6)


def test_bf16_accumulates_in_f32():
    # 4096 bf16 ones: naive bf16 accumulation saturates at 256
    t = {"x": jnp.ones((4096,), jnp.bfloat16)}
    assert float(treemath.tree_sqnorm(t)) == 4096.0


def test_tree_ravel_round_trip():
    t = _rand_tree(0)
    vec, unravel = treemath.tree_ravel(t)
    assert vec.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(vec), _np_flat(t).astype(np.float32))
    back = unravel(vec)
    assert jax.tree.structure(back) == jax.tree.structure(t)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), back, t)


def test_tree_ravel_round_trip_preserves_dtype():
    t = _rand_tree(3, jnp.bfloat16)
    vec, unravel = treemath.tree_ravel(t)
    back = unravel(vec)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(back))


def test_tree_ravel_stacked_matches_per_client_ravel():
    trees = [_rand_tree(i) for i in range(3)]
    stacked = jax.tree.map(lambda *x: jnp.stack(x), *trees)
    buf, unravel = treemath.tree_ravel_stacked(stacked)
    assert buf.shape[0] == 3 and buf.dtype == jnp.float32
    for k, t in enumerate(trees):
        np.testing.assert_allclose(np.asarray(buf[k]),
                                   _np_flat(t).astype(np.float32))
    # unravel maps an (N,) row back to the UNSTACKED structure
    back = unravel(buf[1])
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6), back, trees[1])


def test_ravel_consistent_with_tree_reductions():
    a, b = _rand_tree(0), _rand_tree(1)
    va, _ = treemath.tree_ravel(a)
    vb, _ = treemath.tree_ravel(b)
    np.testing.assert_allclose(float(jnp.dot(va, vb)),
                               float(treemath.tree_dot(a, b)), rtol=1e-5)
    np.testing.assert_allclose(float(jnp.dot(va, va)),
                               float(treemath.tree_sqnorm(a)), rtol=1e-5)


def test_unravel_cache_reused():
    t = _rand_tree(0)
    _, u1 = treemath.tree_ravel(t)
    _, u2 = treemath.tree_ravel(_rand_tree(5))  # same structure/shapes/dtypes
    assert u1 is u2
    _, u3 = treemath.tree_ravel({"z": jnp.zeros((3,))})
    assert u3 is not u1


def test_segment_mask_alignment():
    t = _rand_tree(0)
    keep = [True, False, True]  # flatten order: x, y[0], y[1]
    m = np.asarray(treemath.segment_mask(t, keep))
    sizes = [x.size for x in jax.tree.leaves(t)]
    assert m.shape == (sum(sizes),)
    np.testing.assert_array_equal(m[: sizes[0]], 1.0)
    np.testing.assert_array_equal(m[sizes[0]: sizes[0] + sizes[1]], 0.0)
    np.testing.assert_array_equal(m[sizes[0] + sizes[1]:], 1.0)
