"""Property tests for core/weighting.py over seeded random draws.

Unlike test_weighting.py (hypothesis, skipped when the package is
missing), these run everywhere: each test sweeps many random angle/size
draws with a seeded numpy generator, so CPU CI always exercises the
simplex, monotonicity, and Theorem-2 properties.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import weighting

SEEDS = [0, 1, 2, 3, 4]
DRAWS_PER_SEED = 20


def _draw(rng):
    k = int(rng.integers(2, 17))
    theta = rng.uniform(0.0, np.pi, size=k)
    sizes = rng.uniform(1.0, 1e4, size=k)
    return jnp.asarray(theta), jnp.asarray(sizes)


@pytest.mark.parametrize("seed", SEEDS)
def test_fedadp_weights_form_simplex(seed):
    rng = np.random.default_rng(seed)
    for _ in range(DRAWS_PER_SEED):
        theta, sizes = _draw(rng)
        w = np.asarray(weighting.fedadp_weights(theta, sizes))
        assert np.all(w >= 0)
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-5)


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("alpha", [2.0, 5.0, 10.0])
def test_gompertz_monotone_decreasing_in_theta(seed, alpha):
    rng = np.random.default_rng(seed)
    for _ in range(DRAWS_PER_SEED):
        th = np.sort(rng.uniform(0.0, np.pi, size=int(rng.integers(2, 17))))
        f = np.asarray(weighting.gompertz(jnp.asarray(th), alpha))
        assert np.all(np.diff(f) <= 1e-6), (alpha, th, f)
        assert np.all(f >= 0.0) and np.all(f <= alpha + 1e-6)


@pytest.mark.parametrize("seed", SEEDS)
def test_theorem2_fedadp_contribution_dominates_fedavg(seed):
    """Thm. 2: E_{i|t}[cos theta_i] under FedAdp weights >= under FedAvg
    (equal data sizes — Chebyshev's sum inequality applies because both
    weight orders track the contribution order)."""
    rng = np.random.default_rng(seed)
    for _ in range(DRAWS_PER_SEED):
        k = int(rng.integers(2, 17))
        theta = jnp.asarray(rng.uniform(0.0, np.pi * 0.999, size=k))
        d = jnp.ones((k,))
        cos = jnp.cos(theta)
        e_adp = weighting.expected_contribution(
            weighting.fedadp_weights(theta, d), cos)
        e_avg = weighting.expected_contribution(
            weighting.fedavg_weights(d), cos)
        assert float(e_adp) >= float(e_avg) - 1e-6


@pytest.mark.parametrize("seed", SEEDS)
def test_equal_angles_reduce_to_fedavg(seed):
    rng = np.random.default_rng(seed)
    for _ in range(DRAWS_PER_SEED):
        k = int(rng.integers(2, 13))
        th = float(rng.uniform(0.0, np.pi))
        d = jnp.asarray(rng.uniform(1.0, 1e4, size=k))
        w_adp = np.asarray(weighting.fedadp_weights(jnp.full((k,), th), d))
        w_avg = np.asarray(weighting.fedavg_weights(d))
        np.testing.assert_allclose(w_adp, w_avg, rtol=1e-5)
