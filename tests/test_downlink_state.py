"""Per-client downlink-delta state: the shared-broadcast regression pins.

The pre-ring repo carried ONE shared (N,) previous-broadcast vector, which
silently assumed every client receives every broadcast. Under subset
selection (clients_per_round < num_clients) — and under buffered
admission, where a client's base is fixed at admission time — a client
that sat out rounds would have decoded the next delta against a base it
never held. These tests pin the fixed contract:

* a re-selected client replaying the ring's delta reconstructions from
  the base it ACTUALLY holds lands bitwise on the server's broadcast
  head (the failing regression of the shared-vector design);
* a client more than `downlink_ring` versions behind cannot replay and
  is resynced with a full model (`resync_mask`, `client_decode` raises);
* per-client down-bytes (delta payloads vs full resyncs) surface through
  the tel/* keys and degenerate to the static K-unicast accounting under
  full participation;
* the buffered twin fixes the decode base at ADMISSION time: a client
  whose report is in flight keeps its pull version until re-admitted.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import transport
from repro.core import fl
from repro.transport import downlink

C, K, TAU, B, D = 6, 2, 2, 4, 8


def _problem(seed=0):
    rng = np.random.default_rng(seed)
    params = {"w": jnp.zeros((D, 1), jnp.float32),
              "b": jnp.zeros((1,), jnp.float32)}
    X = rng.normal(size=(C, TAU, B, D)).astype(np.float32)
    w_true = rng.normal(size=(C, D, 1)).astype(np.float32)
    Y = np.einsum("ctbd,cde->ctbe", X, w_true)

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    return params, loss_fn, np.asarray(X), np.asarray(Y)


def _cfg(**kw):
    base = dict(num_clients=C, clients_per_round=K, local_steps=TAU,
                method="fedadp", base_lr=0.1, downlink="int8",
                downlink_delta=True)
    base.update(kw)
    return fl.FLConfig(**base)


def _run(cfg, schedule, loss_fn, params, X, Y):
    """Drive round_fn through an explicit per-round selection schedule,
    yielding (round, sel, state, metrics) after each round."""
    rf = jax.jit(fl.make_round_fn(loss_fn, cfg))
    st = fl.init_round_state(cfg, params)
    sizes = jnp.full((cfg.clients_per_round,), 10.0, jnp.float32)
    for r, sel in enumerate(schedule):
        batches = (jnp.asarray(X[sel]), jnp.asarray(Y[sel]))
        st, m = rf(st, batches, jnp.asarray(sel, jnp.int32), sizes)
        yield r, sel, st, m


# --------------------------------------------- the failing regression


def test_reselected_client_decodes_servers_broadcast():
    """THE regression: client 0 pulls at round 0, sits out rounds 1-3
    while the broadcast chain advances, and is re-selected at round 4.
    Decoding from the base it actually holds (replaying the ring's
    deltas in version order) must equal the server's head BITWISE.

    The pre-fix shared prev-broadcast design would have had the client
    apply only the LAST delta to its stale base — asserted below to
    differ, so this test discriminates the bug, not just the happy path.
    """
    params, loss_fn, X, Y = _problem()
    schedule = [[0, 1], [2, 3], [4, 5], [1, 2], [0, 3]]
    base, base_ver = None, downlink.NEVER_PULLED
    for r, sel, st, _ in _run(_cfg(), schedule, loss_fn, params, X, Y):
        assert int(st.bcast.head_ver) == r
        if 0 not in sel:
            continue
        if base_ver == downlink.NEVER_PULLED:
            # first pull: full-model resync (the sim hands the head)
            assert bool(downlink.resync_mask(
                jnp.int32(base_ver), int(st.bcast.head_ver),
                _cfg().downlink_ring))
        else:
            decoded = downlink.client_decode(
                st.bcast, jnp.asarray(base), base_ver)
            head = np.asarray(st.bcast.head)
            assert np.asarray(decoded).tobytes() == head.tobytes()
            # the shared-vector decode (stale base + last delta only)
            # would NOT have reconstructed the broadcast:
            last = np.asarray(st.bcast.ring)[r % _cfg().downlink_ring]
            assert (base + last).tobytes() != head.tobytes()
        base, base_ver = np.asarray(st.bcast.head), int(st.bcast.head_ver)
    assert base_ver == 4  # client 0 re-pulled at the last round
    # ver tracks the last pull of every client per the schedule
    assert st.bcast.ver.tolist() == [4, 3, 3, 4, 2, 2]


def test_client_behind_the_ring_needs_full_resync():
    """With a 2-deep ring, a client 3+ versions behind cannot replay the
    overwritten deltas: resync_mask flags it, client_decode refuses, and
    after re-selection its version is current again."""
    params, loss_fn, X, Y = _problem()
    cfg = _cfg(downlink_ring=2)
    schedule = [[0, 1], [2, 3], [4, 5], [1, 2], [0, 3]]
    states = [st for _, _, st, _ in _run(cfg, schedule, loss_fn, params,
                                         X, Y)]
    st3, st4 = states[3], states[4]
    # before round 4, client 0 last pulled version 0; version 4 is 4
    # behind — outside the 2-deep ring
    assert int(st3.bcast.ver[0]) == 0
    assert bool(downlink.resync_mask(st3.bcast.ver[0], 4,
                                     cfg.downlink_ring))
    with pytest.raises(ValueError, match="resync"):
        downlink.client_decode(st4.bcast, st4.bcast.ring[0], 0)
    # a 1-behind client still delta-decodes under the same ring
    assert not bool(downlink.resync_mask(jnp.int32(3), 4,
                                         cfg.downlink_ring))
    assert st4.bcast.ver.tolist() == [4, 3, 3, 4, 2, 2]


def test_full_participation_every_round_is_one_delta():
    """clients_per_round == num_clients: after the round-0 resync, every
    client is exactly one version behind every round — the ring design
    degenerates to the shared-vector accounting (K delta payloads)."""
    params, loss_fn, X, Y = _problem()
    cfg = _cfg(clients_per_round=C, telemetry="node")
    n = fl.param_count(params)
    unit = transport.wire_bytes(1, n, cfg.downlink)
    schedule = [list(range(C))] * 3
    for r, _, st, m in _run(cfg, schedule, loss_fn, params, X, Y):
        assert st.bcast.ver.tolist() == [r] * C
        assert float(m["tel/bytes_down"]) == C * unit
        if r == 0:  # everyone resyncs on the first broadcast
            assert float(m["tel/bytes_down_full"]) == C * unit
            assert float(m["tel/bytes_down_delta"]) == 0.0
        else:  # everyone replays exactly one delta
            assert float(m["tel/bytes_down_delta"]) == C * unit
            assert float(m["tel/bytes_down_full"]) == 0.0
        # the static accounting is the degenerate case
        rb = transport.round_bytes(C, n, cfg.transport, cfg.downlink)
        assert float(m["tel/bytes_down"]) == rb["down"]


def test_per_client_down_bytes_follow_staleness():
    """Subset selection: a delta-served client pays one payload per
    missed version (behind x unit); a resync pays one full unit."""
    params, loss_fn, X, Y = _problem()
    cfg = _cfg(telemetry="node")
    n = fl.param_count(params)
    unit = transport.wire_bytes(1, n, cfg.downlink)
    schedule = [[0, 1], [2, 3], [0, 4]]
    seen = []
    for r, _, st, m in _run(cfg, schedule, loss_fn, params, X, Y):
        seen.append((float(m["tel/bytes_down_delta"]),
                     float(m["tel/bytes_down_full"]),
                     float(m["tel/bytes_down"])))
    # round 0: both fresh -> 2 full; round 1: both fresh -> 2 full;
    # round 2: client 0 is 2 versions behind (2 delta payloads),
    # client 4 fresh (1 full)
    assert seen[0] == (0.0, 2 * unit, 2 * unit)
    assert seen[1] == (0.0, 2 * unit, 2 * unit)
    assert seen[2] == (2 * unit, 1 * unit, 3 * unit)


def test_off_path_carries_no_byte_metrics():
    """telemetry=None: the dynamic byte accounting must stay out of the
    metrics dict (the standing zero-overhead off-path contract)."""
    params, loss_fn, X, Y = _problem()
    for _, _, _, m in _run(_cfg(), [[0, 1]], loss_fn, params, X, Y):
        assert not [k for k in m if k.startswith("tel/")]


# ------------------------------------------------------- buffered twin


def test_buffered_base_is_fixed_at_admission_time():
    """Buffered admission: a client's decode base is the broadcast it
    pulled when ADMITTED; while its report is in flight its version must
    not advance, and on re-admission it replays every delta since its
    admission-time pull — bitwise onto the server head."""
    TK = 3  # buffered concurrency slots
    rng = np.random.default_rng(1)
    params = {"w": jnp.zeros((D, 1), jnp.float32),
              "b": jnp.zeros((1,), jnp.float32)}
    X = rng.normal(size=(C, TAU, B, D)).astype(np.float32)
    Y = np.einsum("ctbd,cde->ctbe", X,
                  rng.normal(size=(C, D, 1)).astype(np.float32))

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    # client 0's tick-0 report straggles 2 ticks; buffer_m=2 keeps
    # flushing without it. Client 5 is never offered: stays NEVER_PULLED.
    # (arrival arrays are (T, K): per CANDIDATE slot, not per client.)
    delays = np.zeros((5, TK), np.int32)
    delays[0, 0] = 2
    drops = np.zeros((5, TK), bool)
    cfg = fl.FLConfig(num_clients=C, clients_per_round=TK, local_steps=TAU,
                      method="fedadp", base_lr=0.1, downlink="int8",
                      downlink_delta=True, aggregation="buffered",
                      buffer_m=2)
    rf = jax.jit(fl.make_round_fn(
        loss_fn, cfg,
        arrival_fn=repro.fixed_arrival_schedule(delays, drops)))
    st = fl.init_round_state(cfg, params)
    sizes = jnp.full((TK,), 10.0, jnp.float32)
    schedule = [[0, 1, 2], [0, 3, 4], [0, 1, 2], [0, 3, 4]]
    states = []
    for sel in schedule:
        batches = (jnp.asarray(X[sel]), jnp.asarray(Y[sel]))
        st, m = rf(st, batches, jnp.asarray(sel, jnp.int32), sizes)
        states.append(st)

    # tick 0 admitted client 0 at version 0; ticks 1-2 re-offer it but
    # its report is in flight (busy) -> NOT re-admitted, version frozen
    assert int(states[0].bcast.ver[0]) == 0
    assert int(states[1].bcast.ver[0]) == 0
    assert int(states[2].bcast.ver[0]) == 0
    # its report landed and flushed by tick 2 -> tick 3 re-admits it: it
    # replays deltas 1..3 onto its ADMISSION-TIME base (version 0)
    assert int(states[3].bcast.ver[0]) == 3
    base = states[0].bcast.head  # what client 0 pulled at admission
    decoded = downlink.client_decode(states[3].bcast, base, 0)
    assert (np.asarray(decoded).tobytes()
            == np.asarray(states[3].bcast.head).tobytes())
    # the never-offered client still needs a full model
    assert int(states[3].bcast.ver[5]) == downlink.NEVER_PULLED


def test_buffered_bytes_count_admitted_pulls_only():
    """Busy (in-flight) and dropped candidates never pulled this tick's
    broadcast: the tel/* byte split charges admitted clients only."""
    rng = np.random.default_rng(2)
    params = {"w": jnp.zeros((D, 1), jnp.float32),
              "b": jnp.zeros((1,), jnp.float32)}
    X = rng.normal(size=(4, TAU, B, D)).astype(np.float32)
    Y = np.einsum("ctbd,cde->ctbe", X,
                  rng.normal(size=(4, D, 1)).astype(np.float32))

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    delays = np.zeros((3, 2), np.int32)
    drops = np.zeros((3, 2), bool)
    drops[0, 1] = True  # client 1's tick-0 report is lost in transit
    cfg = fl.FLConfig(num_clients=4, clients_per_round=2, local_steps=TAU,
                      method="fedadp", base_lr=0.1, downlink="int8",
                      downlink_delta=True, aggregation="buffered",
                      buffer_m=1, telemetry="node")
    rf = jax.jit(fl.make_round_fn(
        loss_fn, cfg,
        arrival_fn=repro.fixed_arrival_schedule(delays, drops)))
    st = fl.init_round_state(cfg, params)
    n = fl.param_count(params)
    unit = transport.wire_bytes(1, n, cfg.downlink)
    sizes = jnp.full((2,), 10.0, jnp.float32)
    sel = jnp.asarray([0, 1], jnp.int32)
    batches = (jnp.asarray(X[:2]), jnp.asarray(Y[:2]))
    # tick 0: client 0 admitted (full resync), client 1 dropped in
    # transit — it never pulled, so only ONE full payload is charged
    st, m = rf(st, batches, sel, sizes)
    assert float(m["tel/bytes_down"]) == 1 * unit
    assert float(m["tel/bytes_down_full"]) == 1 * unit
    assert int(st.bcast.ver[1]) == downlink.NEVER_PULLED
    # tick 1: client 0's report flushed at tick 0, so it re-admits at
    # one version behind (1 delta payload); client 1 resyncs (1 full)
    st, m = rf(st, batches, sel, sizes)
    assert float(m["tel/bytes_down_delta"]) == 1 * unit
    assert float(m["tel/bytes_down_full"]) == 1 * unit
    assert st.bcast.ver.tolist()[:2] == [1, 1]


# ----------------------------------------------------- unit-level pins


def test_advance_broadcast_ring_slots_and_versions():
    n = 5
    bs = downlink.init_broadcast_state(n, num_clients=3, ring=2)
    assert int(bs.head_ver) == downlink.NEVER_PULLED
    assert bs.ver.tolist() == [downlink.NEVER_PULLED] * 3
    for v in range(4):
        d = jnp.full((n,), float(v + 1), jnp.float32)
        bs = downlink.advance_broadcast(bs, d)
        assert int(bs.head_ver) == v
        assert float(bs.ring[v % 2][0]) == v + 1
    # head is the running chain; ring holds the LAST TWO deltas only
    assert float(bs.head[0]) == 1 + 2 + 3 + 4
    assert [float(r[0]) for r in bs.ring] == [3.0, 4.0]


def test_init_broadcast_state_rejects_bad_ring():
    with pytest.raises(ValueError, match="ring"):
        downlink.init_broadcast_state(4, num_clients=2, ring=0)
