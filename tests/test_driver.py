"""Device-resident driver tests: the scanned == stepwise contract, the
device data pipeline, partial participation across all three engines, and
the delta-encoded downlink.

The central pin: `FedServer.run_scanned` (chunked `lax.scan` over rounds)
and `FedServer.run` (one jit dispatch per round) share ONE compiled step
— selection, per-client epoch batching, the round, and the eval all run
from the device RNG inside it — so R scanned rounds must reproduce R
stepwise rounds to 1e-5, under full participation AND subset selection,
including the early-exit/rounds-to-target bookkeeping.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import transport
from repro.core import driver, fl
from repro.core.server import FedServer, _epoch_batcher
from repro.data import synthetic
from repro.data.synthetic import Dataset

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "convergence.json")


def _small_task(seed=0):
    train, test = synthetic.make_image_task(seed=seed, num_train=3000,
                                            num_test=400)
    nodes = synthetic.make_federated(
        train, [("iid", None)] * 2 + [("xclass", 1)] * 2,
        samples_per_node=200, seed=1)
    return nodes, test


def _servers(cfg, seed=0):
    nodes, test = _small_task()
    return (FedServer("mlr", cfg, nodes, test, batch_size=50, seed=seed),
            FedServer("mlr", cfg, nodes, test, batch_size=50, seed=seed))


# ------------------------------------------------ scanned == stepwise


@pytest.mark.parametrize("method", ["fedadp", "fedavg"])
def test_scanned_matches_stepwise(method):
    """R scanned rounds == R stepwise steps to 1e-5 (shared device RNG:
    selection and batching happen inside the one step both paths run)."""
    cfg = fl.FLConfig(num_clients=4, clients_per_round=4, local_steps=4,
                      method=method, base_lr=0.05)
    s_loop, s_scan = _servers(cfg)
    h_loop = s_loop.run(6, eval_every=2)
    h_scan = s_scan.run_scanned(6, eval_every=2, block=4)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5),
        s_loop.state.params, s_scan.state.params)
    np.testing.assert_allclose(s_loop.state.angle.smoothed,
                               s_scan.state.angle.smoothed, atol=1e-5)
    np.testing.assert_allclose(h_loop.loss, h_scan.loss, rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(h_loop.accuracy, h_scan.accuracy, atol=1e-6)
    assert len(h_scan.accuracy) == 3  # eval_every=2 over 6 rounds


def test_scanned_matches_stepwise_subset_selection():
    """Client sampling comes from the shared device RNG, so the scanned
    and stepwise paths must pick the SAME cohorts — per-client Eq. 9
    participation counts agree exactly, trajectories to 1e-5."""
    cfg = fl.FLConfig(num_clients=4, clients_per_round=2, local_steps=4,
                      method="fedadp", base_lr=0.05)
    s_loop, s_scan = _servers(cfg)
    h_loop = s_loop.run(7, eval_every=2)
    h_scan = s_scan.run_scanned(7, eval_every=2, block=3)
    assert (s_loop.state.angle.count.tolist()
            == s_scan.state.angle.count.tolist())
    assert int(np.sum(s_loop.state.angle.count)) == 7 * 2
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5),
        s_loop.state.params, s_scan.state.params)
    np.testing.assert_allclose(h_loop.loss, h_scan.loss, rtol=1e-5,
                               atol=1e-6)


def test_scanned_early_exit_matches_stepwise_target_semantics():
    """rounds_to_target must be the exact first eval round at/above the
    target in BOTH paths, even though the scan runs to its block edge."""
    cfg = fl.FLConfig(num_clients=4, clients_per_round=4, local_steps=4,
                      method="fedadp", base_lr=0.05)
    s_loop, s_scan = _servers(cfg)
    # a target low enough to be hit quickly on the tiny task
    h_loop = s_loop.run(20, target_acc=0.15, eval_every=2)
    h_scan = s_scan.run_scanned(20, target_acc=0.15, eval_every=2, block=8)
    assert h_loop.rounds_to_target is not None
    assert h_scan.rounds_to_target == h_loop.rounds_to_target
    assert len(h_scan.loss) == len(h_loop.loss) == h_loop.rounds_to_target
    np.testing.assert_allclose(h_loop.accuracy, h_scan.accuracy, atol=1e-6)


def test_in_scan_eval_matches_host_eval():
    """The device-side eval (inside the compiled step) and the host-side
    `evaluate()` measure the same accuracy of the same params."""
    cfg = fl.FLConfig(num_clients=4, clients_per_round=4, local_steps=4,
                      method="fedadp", base_lr=0.05)
    s, _ = _servers(cfg)
    m = s.step(eval_every=1)
    assert m["accuracy"] >= 0.0
    assert abs(float(m["accuracy"]) - s.evaluate()) < 1e-6


# ------------------------------------------------ device data pipeline


def test_stack_nodes_rejects_batch_larger_than_node():
    """tau = 0 used to crash the numpy batcher with an opaque reshape
    error; the device pipeline must refuse with the node named."""
    nodes = [Dataset(np.zeros((60, 4, 4, 1), np.float32),
                     np.zeros((60,), np.int32)),
             Dataset(np.zeros((30, 4, 4, 1), np.float32),
                     np.zeros((30,), np.int32))]
    with pytest.raises(ValueError, match="node 1"):
        driver.stack_nodes(nodes, batch_size=50)


def test_epoch_batcher_rejects_batch_larger_than_dataset():
    """The host-side reference batcher raises the same clear error."""
    ds = Dataset(np.zeros((30, 4, 4, 1), np.float32),
                 np.zeros((30,), np.int32))
    with pytest.raises(ValueError, match="batch_size=50"):
        next(_epoch_batcher(ds, batch_size=50, seed=0))


def test_stack_nodes_rejects_unequal_tau():
    nodes = [Dataset(np.zeros((100, 2), np.float32),
                     np.zeros((100,), np.int32)),
             Dataset(np.zeros((200, 2), np.float32),
                     np.zeros((200,), np.int32))]
    with pytest.raises(ValueError, match="tau"):
        driver.stack_nodes(nodes, batch_size=50)


def test_epoch_batches_never_sample_padding():
    """Ragged node sizes: the masked permutation must only draw real rows
    (padding is NaN-poisoned here and must never appear), and one epoch
    must not repeat a sample within a client."""
    rng = np.random.default_rng(0)
    nodes = [
        Dataset(rng.normal(size=(110, 3)).astype(np.float32),
                np.arange(110, dtype=np.int32)),
        Dataset(rng.normal(size=(100, 3)).astype(np.float32),
                np.arange(100, dtype=np.int32)),
    ]
    data = driver.stack_nodes(nodes, batch_size=50)
    assert data.tau == 2
    # poison the padding rows: sampling one would go NaN loudly
    x = np.array(data.x)
    x[1, 100:] = np.nan
    data = data._replace(x=jnp.asarray(x))
    xb, yb = driver.epoch_batches(jax.random.key(0), data,
                                  jnp.asarray([0, 1], jnp.int32))
    assert xb.shape == (2, 2, 50, 3)
    assert np.all(np.isfinite(np.asarray(xb)))
    for c in range(2):
        drawn = np.asarray(yb[c]).ravel()
        assert len(set(drawn.tolist())) == 100  # no within-epoch repeats
        assert drawn.max() < len(nodes[c].y)


# ------------------------------- partial participation, all engines


def test_partial_participation_pinned_across_engines():
    """clients_per_round < num_clients under the quantized uplink + EF:
    every engine must (a) advance Eq. 9 participation counts ONLY for the
    selected clients, (b) leave unselected clients' EF rows untouched,
    and (c) agree with the tree reference to 1e-5."""
    rng = np.random.default_rng(0)
    K, C, tau, B, d = 3, 8, 3, 8, 12
    params = {"w": jnp.zeros((d, 1), jnp.float32),
              "b": jnp.zeros((1,), jnp.float32)}
    X = jnp.asarray(rng.normal(size=(K, tau, B, d)).astype(np.float32))
    wt = rng.normal(size=(K, d, 1)).astype(np.float32)
    Y = jnp.asarray(np.einsum("ktbd,kde->ktbe", X, wt))

    def loss_fn(p, b):
        x, y = b
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    mesh = jax.make_mesh((1,), ("data",))
    sel = jnp.asarray([1, 4, 6], jnp.int32)
    sizes = jnp.asarray([10.0, 20.0, 30.0])
    outs = {}
    for engine in ("tree", "flat", "flat_sharded"):
        cfg = fl.FLConfig(num_clients=C, clients_per_round=K,
                          local_steps=tau, method="fedadp", engine=engine,
                          transport="int8", error_feedback=True,
                          base_lr=0.05)
        rf = jax.jit(fl.make_round_fn(
            loss_fn, cfg, mesh=mesh if engine == "flat_sharded" else None))
        st = fl.init_round_state(cfg, params)
        for _ in range(2):
            st, m = rf(st, (X, Y), sel, sizes)
        outs[engine] = st
        assert st.angle.count.tolist() == [0, 2, 0, 0, 2, 0, 2, 0], engine
        ef = np.asarray(st.ef)
        unselected = [0, 2, 3, 5, 7]
        assert np.all(ef[unselected] == 0.0), engine
        assert np.abs(ef[np.asarray(sel)]).sum() > 0.0, engine
    for engine in ("flat", "flat_sharded"):
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5),
            outs["tree"].params, outs[engine].params)
        np.testing.assert_allclose(np.asarray(outs["tree"].ef),
                                   np.asarray(outs[engine].ef), atol=1e-6)
        np.testing.assert_allclose(outs["tree"].angle.smoothed,
                                   outs[engine].angle.smoothed, atol=1e-5)


# ------------------------------------------------ delta-encoded downlink


def test_downlink_delta_roundtrip_tracks_small_diffs():
    """The delta-encoded hop reconstructs within the int8 bound of the
    DIFF — far tighter than compressing the full model when the per-round
    step is small (the whole point of shipping diffs)."""
    rng = np.random.default_rng(0)
    n = transport.CHUNK + 600
    prev = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    vec = prev + 1e-3 * jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    rt = transport.downlink.delta_roundtrip(vec, prev, "int8")
    err_delta = np.abs(np.asarray(rt - vec))
    err_direct = np.abs(np.asarray(
        transport.downlink.broadcast_roundtrip(vec, "int8") - vec))
    # elementwise int8 bound on the diff: half a quant step of the diff
    q = transport.downlink.delta_compress(vec, prev, "int8")
    bound = np.repeat(np.asarray(q.scales)[0], transport.CHUNK)[:n]
    assert np.all(err_delta <= 0.5 * bound * (1 + 1e-6) + 1e-8)
    assert err_delta.max() < 0.1 * err_direct.max()


def test_downlink_delta_stream_never_drifts():
    """Server and clients advance the same reconstruction: replaying the
    compressed diffs client-side lands exactly on the broadcast the round
    function trained its clients from."""
    rng = np.random.default_rng(1)
    n = 3000
    prev = jnp.zeros((n,), jnp.float32)
    model = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    for step in range(4):
        q = transport.downlink.delta_compress(model, prev, "int8")
        prev = transport.downlink.delta_decompress(q, prev)
        model = model + 0.01 * jnp.asarray(
            rng.normal(size=(n,)).astype(np.float32))
    # after several hops the stream still tracks the model to the bound
    # of the LAST diff, not the accumulated model magnitude
    assert float(jnp.max(jnp.abs(prev - model))) < 0.1


def test_downlink_delta_engines_agree():
    """downlink_delta is applied upstream of the engine branch: tree ==
    flat == flat_sharded to 1e-5, the broadcast chain head advancing
    identically."""
    rng = np.random.default_rng(0)
    K, tau, B, d = 4, 3, 8, 12
    params = {"w": jnp.full((d, 1), 0.05, jnp.float32),
              "b": jnp.full((1,), 0.01, jnp.float32)}
    X = jnp.asarray(rng.normal(size=(K, tau, B, d)).astype(np.float32))
    wt = rng.normal(size=(K, d, 1)).astype(np.float32)
    Y = jnp.asarray(np.einsum("ktbd,kde->ktbe", X, wt))

    def loss_fn(p, b):
        x, y = b
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    mesh = jax.make_mesh((1,), ("data",))
    sel = jnp.arange(K, dtype=jnp.int32)
    sizes = jnp.asarray([10.0, 20.0, 30.0, 40.0])
    outs = {}
    for engine in ("tree", "flat", "flat_sharded"):
        cfg = fl.FLConfig(num_clients=K, clients_per_round=K,
                          local_steps=tau, method="fedadp", engine=engine,
                          downlink="int8", downlink_delta=True,
                          base_lr=0.05)
        rf = jax.jit(fl.make_round_fn(
            loss_fn, cfg, mesh=mesh if engine == "flat_sharded" else None))
        st = fl.init_round_state(cfg, params)
        for _ in range(3):
            st, _ = rf(st, (X, Y), sel, sizes)
        outs[engine] = st
        assert st.bcast is not None
        assert np.abs(np.asarray(st.bcast.head)).sum() > 0
        assert int(st.bcast.head_ver) == 2  # three rounds: versions 0..2
    for engine in ("flat", "flat_sharded"):
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5),
            outs["tree"].params, outs[engine].params)
        np.testing.assert_allclose(
            np.asarray(outs["tree"].bcast.head),
            np.asarray(outs[engine].bcast.head), atol=1e-6)


def test_downlink_delta_requires_quantized_downlink():
    def loss_fn(p, b):
        return 0.0

    cfg = fl.FLConfig(num_clients=4, clients_per_round=4, local_steps=2,
                      downlink="f32", downlink_delta=True)
    with pytest.raises(ValueError, match="downlink_delta"):
        fl.make_round_fn(loss_fn, cfg)


def test_downlink_delta_convergence_parity():
    """Delta-encoding the int8 broadcast must not cost rounds: within the
    1.1x acceptance band of the golden f32/f32 reference."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.common import node_spec, run_fl

    with open(GOLDEN) as f:
        g = json.load(f)
    task = g["task"]
    hist, _ = run_fl(
        "fedadp", node_spec(5, 5, 1), rounds=task["max_rounds"],
        target=task["target"], engine=task["engine"], transport="f32",
        downlink="int8", downlink_delta=True, seed=task["seed"],
        eval_every=task["eval_every"])
    ref = g["entries"]["fedadp/f32/f32"]
    assert hist.rounds_to_target is not None
    assert hist.rounds_to_target <= 1.1 * ref + 1, (hist.rounds_to_target,
                                                    ref)


# ------------------------------------------- scanned golden convergence


SCANNED_GOLDEN_CASES = [
    ("fedadp", "f32", "f32"),
    ("fedavg", "f32", "f32"),
    ("fedadp", "int4", "int8"),
]


def test_scanned_driver_reproduces_golden_convergence():
    """Acceptance: the scanned driver reproduces the golden convergence
    table through its OWN path — fedadp <= fedavg, and every re-run wire
    within the 1.1x band of its golden entry in both directions."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.common import node_spec, run_fl

    with open(GOLDEN) as f:
        g = json.load(f)
    task = g["task"]
    got = {}
    for method, uplink, downlink in SCANNED_GOLDEN_CASES:
        hist, _ = run_fl(
            method, node_spec(5, 5, 1), rounds=task["max_rounds"],
            target=task["target"], engine=task["engine"],
            transport=uplink, downlink=downlink,
            group_size=task["group_size"], seed=task["seed"],
            eval_every=task["eval_every"], scan=True, scan_block=10)
        key = f"{method}/{uplink}/{downlink}"
        got[key] = hist.rounds_to_target
        golden = g["entries"][key]
        assert got[key] is not None, key
        assert got[key] <= 1.1 * golden and golden <= 1.1 * got[key], (
            key, got[key], golden)
    assert got["fedadp/f32/f32"] <= got["fedavg/f32/f32"]


def test_scanned_flat_sharded_8device_subprocess():
    """The scanned driver composes with the client-sharded engine: on an
    8-way host-device mesh, run_scanned == stepwise run for
    engine="flat_sharded" (shard_map inside lax.scan)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        from repro.core import fl
        from repro.core.server import FedServer
        from repro.data import synthetic
        train, test = synthetic.make_image_task(seed=0, num_train=3000,
                                                num_test=400)
        nodes = synthetic.make_federated(
            train, [("iid", None)] * 4 + [("xclass", 1)] * 4,
            samples_per_node=200, seed=1)
        mesh = jax.make_mesh((8,), ("data",))
        cfg = fl.FLConfig(num_clients=8, clients_per_round=8, local_steps=4,
                          method="fedadp", engine="flat_sharded",
                          transport="int8", base_lr=0.05)
        servers = [FedServer("mlr", cfg, nodes, test, batch_size=50,
                             seed=0, mesh=mesh) for _ in range(2)]
        h1 = servers[0].run(6, eval_every=2)
        h2 = servers[1].run_scanned(6, eval_every=2, block=4)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5),
            servers[0].state.params, servers[1].state.params)
        np.testing.assert_allclose(h1.loss, h2.loss, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(h1.accuracy, h2.accuracy, atol=1e-6)
        print("SCANNED_SHARDED_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SCANNED_SHARDED_OK" in out.stdout, out.stderr[-2000:]
