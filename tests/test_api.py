"""Public API surface tests: the `repro` facade, the unified run()
entrypoint, and FLConfig.validate().

The facade (`src/repro/__init__.py`) is the supported import surface for
scripts/examples/benchmarks — `__all__` is pinned HERE so growing it is a
deliberate, reviewed act. `FedServer.run` is the single run entrypoint
(mode="stepwise" | "scanned"); `run_scanned` survives only as a
warn-once deprecation shim. `FLConfig.validate()` concentrates every
cross-field invariant and is called by both `make_round_fn` and
`init_round_state`, so a bad config fails loudly before anything is
allocated or traced.
"""
import inspect
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import fl
from repro.data import synthetic

# ------------------------------------------------------------- facade


def test_facade_all_is_pinned():
    assert repro.__all__ == [
        "CSVSink",
        "FLConfig",
        "FedServer",
        "History",
        "JSONLSink",
        "MemorySink",
        "RoundState",
        "SpanTimer",
        "fixed_arrival_schedule",
        "init_round_state",
        "make_round_fn",
        "run_manifest",
        "state_from_tree",
        "state_to_tree",
        "telemetry",
    ]
    for name in repro.__all__:
        assert getattr(repro, name) is not None


def test_facade_reexports_are_the_real_objects():
    assert repro.FLConfig is fl.FLConfig
    assert repro.RoundState is fl.RoundState
    assert repro.make_round_fn is fl.make_round_fn


# ------------------------------------------------------ run entrypoint


def test_run_signature_is_pinned():
    sig = inspect.signature(repro.FedServer.run)
    params = list(sig.parameters)
    assert params == ["self", "rounds", "target_acc", "eval_every",
                      "mode", "verbose", "block", "ckpt_dir",
                      "ckpt_every_blocks", "ckpt_keep", "sink",
                      "telemetry_every"]
    p = sig.parameters
    assert p["mode"].kind is inspect.Parameter.KEYWORD_ONLY
    assert p["mode"].default == "stepwise"
    assert p["target_acc"].default is None
    assert p["eval_every"].default == 1
    assert p["block"].default == 8


def _tiny_server(seed=0):
    train, test = synthetic.make_image_task(seed=0, num_train=1500,
                                            num_test=200)
    nodes = synthetic.make_federated(
        train, [("iid", None)] * 2, samples_per_node=150, seed=1)
    cfg = repro.FLConfig(num_clients=2, clients_per_round=2, local_steps=3,
                         base_lr=0.05)
    return repro.FedServer("mlr", cfg, nodes, test, batch_size=50,
                           seed=seed)


def test_run_rejects_unknown_mode():
    s = _tiny_server()
    with pytest.raises(ValueError, match="unknown mode 'turbo'"):
        s.run(1, mode="turbo")


def test_run_scanned_shim_warns_once_and_delegates():
    s_shim, s_run = _tiny_server(), _tiny_server()
    repro.FedServer._warned_run_scanned = False
    with pytest.warns(DeprecationWarning, match="run_scanned"):
        h_shim = s_shim.run_scanned(4, eval_every=2, block=2)
    # warn-once: a second call must stay silent
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        s_shim.run_scanned(2, eval_every=2, block=2)
    h_run = s_run.run(4, eval_every=2, mode="scanned", block=2)
    np.testing.assert_allclose(h_shim.loss, h_run.loss, rtol=1e-6)
    np.testing.assert_allclose(h_shim.accuracy, h_run.accuracy, atol=1e-6)


# -------------------------------------------------- FLConfig.validate


def _cfg(**kw):
    base = dict(num_clients=10, clients_per_round=10, local_steps=4)
    base.update(kw)
    return repro.FLConfig(**base)


def test_validate_returns_self_for_chaining():
    cfg = _cfg()
    assert cfg.validate() is cfg


BAD_CONFIGS = [
    (dict(mode="lockstep"), "unknown mode"),
    (dict(method="fedsgd"), "unknown method"),
    (dict(engine="gpu"), "unknown engine"),
    (dict(transport="int2"), "unknown transport"),
    (dict(downlink="int4"), "unknown downlink"),
    (dict(error_feedback=True), "transport='f32' has none"),
    (dict(aggregation="async"), "unknown aggregation"),
    (dict(aggregation="buffered", mode="sequential"),
     "requires mode='parallel'"),
    (dict(aggregation="buffered", stale_angles=True), "stale_angles"),
    (dict(aggregation="buffered", buffer_m=11), "buffer_m=11 must be in"),
    (dict(aggregation="buffered", staleness_beta=-0.5),
     "staleness_beta=-0.5 must be >= 0"),
    (dict(aggregation="buffered", straggle_prob=1.5),
     "straggle_prob=1.5 must be a"),
    (dict(aggregation="buffered", dropout_prob=-0.1),
     "dropout_prob=-0.1 must be a"),
    (dict(aggregation="buffered", straggle_prob=0.2, straggle_max=0),
     "straggle_max=0 must be >= 1"),
    (dict(buffer_m=5), "requires aggregation='buffered'"),
    (dict(straggle_prob=0.2), "requires aggregation='buffered'"),
    (dict(dropout_prob=0.1), "requires aggregation='buffered'"),
]


@pytest.mark.parametrize("kw,match", BAD_CONFIGS,
                         ids=[m for _, m in BAD_CONFIGS])
def test_validate_rejects(kw, match):
    with pytest.raises(ValueError, match=match):
        _cfg(**kw).validate()


def test_invalid_config_fails_before_allocation_and_tracing():
    """Both entry points run validate(): neither a round function nor a
    RoundState can be built from an invalid config."""
    bad = _cfg(buffer_m=5)  # buffered knob without aggregation="buffered"
    params = {"w": jnp.zeros((3, 2)), "b": jnp.zeros((2,))}
    with pytest.raises(ValueError, match="requires aggregation='buffered'"):
        repro.init_round_state(bad, params)
    with pytest.raises(ValueError, match="requires aggregation='buffered'"):
        repro.make_round_fn(lambda p, b: 0.0, bad)
