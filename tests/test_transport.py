"""Bidirectional wire: wire-format round trips, fused-dequant kernel
parity, end-to-end engine equivalence per (uplink, downlink) pair, and
error-feedback carry in both directions.

The transport contract (ROADMAP): transport="f32" is the reference uplink
wire format and downlink="f32" the reference broadcast; the tree engine
never reads quantized buffers directly — it dequantizes back to the
stacked tree and runs the per-leaf reference reductions. The fused
kernels (`round_stats_q{,4}`, `weighted_agg_q{,4}`) must therefore match
the dequantize-then-f32 oracles bit-for-tolerance, which makes
tree == flat == flat_sharded hold under every transport pair.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import transport
from repro.core import fl, fl_shard_map, treemath
from repro.kernels import ref, round_stats, weighted_agg
from repro.transport.quantize import CHUNK

# K values straddling the K_TILE=32 client-chunk boundary (degenerate
# single chunk / one full + ragged chunk / exact multiples), N values
# straddling the CHUNK=ROWS*LANE=16384 scale-chunk boundary.
CHUNK_KS = [1, 33, 64]
NS = [100, CHUNK + 1, 2 * CHUNK + 600]
# int4 scale-group widths: sub-(kernel-tile-row) groups (32 < 256 bytes x
# 2 nibbles — many groups per tile row), row-straddling (512), and the
# degenerate one-group-per-chunk case (== CHUNK, scales 1:1 with tiles).
GROUP_SIZES = [32, 512, CHUNK]


def _chunky(key, k, n, block=CHUNK):
    """(k, n) normal data whose per-block magnitude varies by orders of
    magnitude, so a kernel reading the WRONG scale column fails loudly."""
    x = jax.random.normal(key, (k, n), jnp.float32)
    cols = jnp.arange(n) // block
    return x * (10.0 ** (cols % 5).astype(jnp.float32))[None, :]


# ---------------------------------------------------------------- quantize


@pytest.mark.parametrize("n", NS)
def test_int8_roundtrip_error_bound(n):
    """|x - deq(quant(x))| <= scale/2 elementwise — round-to-nearest with
    s = absmax/127 never clips, so half an int8 step bounds the error."""
    x = _chunky(jax.random.key(0), 5, n)
    q = transport.quantize(x, "int8")
    assert q.values.dtype == jnp.int8
    assert q.scales.shape == (5, transport.num_chunks(n))
    err = np.abs(np.asarray(x) - np.asarray(transport.dequantize(q)))
    bound = np.repeat(np.asarray(q.scales), CHUNK, axis=1)[:, :n]
    assert np.all(err <= 0.5 * bound * (1 + 1e-6) + 1e-8)


def test_int8_zero_chunk_is_exact():
    """All-zero chunks must not divide by zero and must reconstruct zero."""
    x = jnp.zeros((2, CHUNK + 7), jnp.float32).at[1, CHUNK + 3].set(3.0)
    q = transport.quantize(x, "int8")
    np.testing.assert_array_equal(np.asarray(q.scales)[:, 0], [1.0, 1.0])
    np.testing.assert_allclose(np.asarray(transport.dequantize(q)),
                               np.asarray(x), atol=3.0 / 254)


def test_bf16_roundtrip_error_bound():
    """bf16 keeps 8 significand bits: relative error <= 2^-8."""
    x = _chunky(jax.random.key(1), 3, 2000)
    rt = transport.roundtrip(x, "bf16")
    np.testing.assert_allclose(np.asarray(rt), np.asarray(x), rtol=2.0**-8)


def test_f32_roundtrip_is_identity():
    x = _chunky(jax.random.key(2), 2, 300)
    np.testing.assert_array_equal(np.asarray(transport.roundtrip(x, "f32")),
                                  np.asarray(x))


@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("gs", GROUP_SIZES)
def test_int4_roundtrip_error_bound(n, gs):
    """|x - deq(quant(x))| <= scale/2 elementwise, per GROUP: round-to-
    nearest with s = absmax(group)/7 never clips, so half an int4 step
    bounds the error."""
    x = _chunky(jax.random.key(3), 5, n, block=gs)
    q = transport.quantize(x, "int4", group_size=gs)
    assert q.values.dtype == jnp.int8
    assert q.values.shape == (5, -(-n // 2))
    assert q.scales.shape == (5, transport.num_groups(n, gs))
    assert (q.transport, q.n, q.group_size) == ("int4", n, gs)
    err = np.abs(np.asarray(x) - np.asarray(transport.dequantize(q)))
    bound = np.repeat(np.asarray(q.scales), gs, axis=1)[:, :n]
    assert np.all(err <= 0.5 * bound * (1 + 1e-6) + 1e-8)


def test_int4_zero_group_is_exact():
    """All-zero groups must not divide by zero and must reconstruct zero
    exactly (zero bytes carry nibble pairs (0, 0) under any scale)."""
    gs = 32
    x = jnp.zeros((2, 3 * gs + 7), jnp.float32).at[1, gs + 3].set(3.0)
    q = transport.quantize(x, "int4", group_size=gs)
    s = np.asarray(q.scales)
    assert s[0, 1] == 1.0 and s[1, 0] == 1.0  # untouched groups
    np.testing.assert_allclose(np.asarray(transport.dequantize(q)),
                               np.asarray(x), atol=3.0 / 14)
    np.testing.assert_array_equal(
        np.asarray(transport.dequantize(q))[0], 0.0)


def test_int4_pack_unpack_roundtrip():
    """pack_int4/unpack_int4 are exact inverses over the full [-7, 7]
    nibble range, including the sign-extension edge values."""
    q = jnp.asarray(
        np.random.default_rng(0).integers(-7, 8, size=(3, 64)), jnp.int32)
    back = transport.unpack_int4(transport.pack_int4(q))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


@pytest.mark.parametrize("gs", [0, 1, 3, 7, 100, CHUNK + 2, 2 * CHUNK])
def test_int4_rejects_bad_group_size(gs):
    """Odd sizes (a byte would straddle groups), non-divisors of CHUNK
    (tiles would straddle groups), and out-of-range sizes all raise."""
    with pytest.raises(ValueError, match="group_size"):
        transport.quantize(jnp.zeros((1, 64)), "int4", group_size=gs)


def test_quantize_rejects_unknown_transport():
    with pytest.raises(ValueError, match="transport"):
        transport.quantize(jnp.zeros((1, 8)), "fp8")


def test_transport_property_and_wire_bytes():
    x = jnp.ones((4, CHUNK + 1), jnp.float32)
    assert transport.quantize(x, "int8").transport == "int8"
    assert transport.quantize(x, "bf16").transport == "bf16"
    assert transport.quantize(x, "f32").transport == "f32"
    assert transport.quantize(x, "int4").transport == "int4"
    n = CHUNK + 1  # 2 scale chunks
    assert transport.wire_bytes(4, n, "f32") == 4 * n * 4
    assert transport.wire_bytes(4, n, "bf16") == 4 * n * 2
    assert transport.wire_bytes(4, n, "int8") == 4 * n + 4 * 2 * 4
    g = transport.num_groups(n, 512)
    assert transport.wire_bytes(4, n, "int4", group_size=512) == (
        4 * -(-n // 2) + 4 * g * 4)
    # the acceptance ratios: int8 moves ~4x and int4 ~8x fewer bytes
    assert transport.wire_bytes(4, n, "f32") > 3.9 * transport.wire_bytes(
        4, n, "int8")
    ratio4 = (transport.wire_bytes(4, n, "int4")
              / transport.wire_bytes(4, n, "f32"))
    assert abs(ratio4 - 0.125) < 0.01, ratio4


def test_round_bytes_reports_both_directions():
    """`transport.round_bytes` covers the downlink too: up is the K-client
    delta uplink, down the K model broadcasts, total their sum."""
    k, n = 8, CHUNK + 1
    rb = transport.round_bytes(k, n, "int4", "int8")
    assert rb["up"] == transport.wire_bytes(k, n, "int4")
    assert rb["down"] == k * transport.wire_bytes(1, n, "int8")
    assert rb["total"] == rb["up"] + rb["down"]
    # reference downlink: f32 broadcast dominates a quantized uplink
    ref_rb = transport.round_bytes(k, n, "int4", "f32")
    assert ref_rb["down"] == k * n * 4
    assert rb["total"] < 0.5 * ref_rb["total"]
    with pytest.raises(ValueError, match="downlink"):
        transport.round_bytes(k, n, "int8", "int4")


def test_tree_unravel_stacked_roundtrip():
    """transport's tree-engine fallback: ravel -> (K, N) -> back to the
    stacked tree, original shapes and dtypes restored."""
    stacked = {
        "a": jax.random.normal(jax.random.key(0), (3, 5, 2), jnp.float32),
        "b": {"c": jax.random.normal(jax.random.key(1), (3, 7), jnp.bfloat16)},
    }
    flat, _ = treemath.tree_ravel_stacked(stacked)
    back = treemath.tree_unravel_stacked(stacked, flat)
    jax.tree.map(
        lambda x, y: (np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32), atol=1e-6),
            None)[1] or None, stacked, back)
    assert back["b"]["c"].dtype == jnp.bfloat16


# ------------------------------------------------- fused-dequant kernels


@pytest.mark.parametrize("k", CHUNK_KS)
@pytest.mark.parametrize("n", NS)
def test_round_stats_q_matches_dequant_oracle(k, n):
    """Fused in-register dequant == dequantize-then-f32 reference, across
    ragged client chunks AND chunk-boundary scales."""
    q = transport.quantize(_chunky(jax.random.key(0), k, n), "int8")
    g = jax.random.normal(jax.random.key(1), (n,), jnp.float32)
    got = round_stats.round_stats_q(q.values, q.scales, g)
    want = ref.round_stats_q(q.values, q.scales, g)
    for gg, ww, name in zip(got, want, ("dots", "sqnorms", "sqg")):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(ww), rtol=1e-3,
                                   atol=1e-2, err_msg=name)


@pytest.mark.parametrize("k", CHUNK_KS)
@pytest.mark.parametrize("n", NS)
def test_weighted_agg_q_matches_dequant_oracle(k, n):
    q = transport.quantize(_chunky(jax.random.key(2), k, n), "int8")
    w = jax.random.uniform(jax.random.key(3), (k,), jnp.float32)
    got = weighted_agg.weighted_agg_q(w, q.values, q.scales)
    want = ref.weighted_agg_q(w, q.values, q.scales)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3,
                               atol=1e-4)


def test_round_stats_q_masked_across_chunk_boundary():
    """Segment mask spanning the scale-chunk boundary + the K=33 ragged
    client chunk: masked fused stats == masked dequant oracle, and the
    mask must actually bite."""
    k, n = 33, 2 * CHUNK + 600
    q = transport.quantize(_chunky(jax.random.key(4), k, n), "int8")
    g = jax.random.normal(jax.random.key(5), (n,), jnp.float32)
    mask = jnp.ones((n,), jnp.float32).at[CHUNK - 500:CHUNK + 500].set(0.0)
    got = round_stats.round_stats_q(q.values, q.scales, g, mask)
    want = ref.round_stats_q(q.values, q.scales, g, mask)
    for gg, ww, name in zip(got, want, ("dots", "sqnorms", "sqg")):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(ww), rtol=1e-3,
                                   err_msg=name)
    full = round_stats.round_stats_q(q.values, q.scales, g)
    assert not np.allclose(np.asarray(got[1]), np.asarray(full[1]))


@pytest.mark.parametrize("k", CHUNK_KS)
@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("gs", GROUP_SIZES)
def test_round_stats_q4_matches_dequant_oracle(k, n, gs):
    """int4 fused in-register unpack+dequant == dequantize-then-f32
    reference, across ragged client chunks AND group boundaries that do
    not align with kernel tile rows (gs=32 packs 16 groups per 128-byte
    row; gs=512 spans two rows; gs=CHUNK covers two tiles per group...
    exercising every scale-expansion regime)."""
    q = transport.quantize(_chunky(jax.random.key(10), k, n, block=gs),
                           "int4", group_size=gs)
    g = jax.random.normal(jax.random.key(11), (n,), jnp.float32)
    got = round_stats.round_stats_q4(q.values, q.scales, g, group_size=gs)
    want = ref.round_stats_q4(q.values, q.scales, g, group_size=gs)
    for gg, ww, name in zip(got, want, ("dots", "sqnorms", "sqg")):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(ww), rtol=2e-3,
                                   atol=1e-2, err_msg=name)


@pytest.mark.parametrize("k", CHUNK_KS)
@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("gs", GROUP_SIZES)
def test_weighted_agg_q4_matches_dequant_oracle(k, n, gs):
    q = transport.quantize(_chunky(jax.random.key(12), k, n, block=gs),
                           "int4", group_size=gs)
    w = jax.random.uniform(jax.random.key(13), (k,), jnp.float32)
    got = weighted_agg.weighted_agg_q4(w, q.values, q.scales, n=n,
                                       group_size=gs)
    want = ref.weighted_agg_q4(w, q.values, q.scales, n=n, group_size=gs)
    assert got.dtype == jnp.float32 and got.shape == (n,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=1e-3)


def test_round_stats_q4_masked_across_boundaries():
    """Segment mask spanning a scale-GROUP boundary, the byte-chunk
    boundary, and the K=33 ragged client chunk all at once: masked fused
    stats == masked dequant oracle, and the mask must actually bite.
    The mask edges are ODD offsets, so the masked-out span starts on a
    high nibble and ends on a low one — the even/odd mask views diverge."""
    k, n, gs = 33, 2 * CHUNK + 600, 512
    q = transport.quantize(_chunky(jax.random.key(14), k, n, block=gs),
                           "int4", group_size=gs)
    g = jax.random.normal(jax.random.key(15), (n,), jnp.float32)
    mask = jnp.ones((n,), jnp.float32).at[gs - 101:CHUNK + 501].set(0.0)
    got = round_stats.round_stats_q4(q.values, q.scales, g, mask,
                                     group_size=gs)
    want = ref.round_stats_q4(q.values, q.scales, g, mask, group_size=gs)
    for gg, ww, name in zip(got, want, ("dots", "sqnorms", "sqg")):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(ww), rtol=2e-3,
                                   atol=1e-2, err_msg=name)
    full = round_stats.round_stats_q4(q.values, q.scales, g, group_size=gs)
    assert not np.allclose(np.asarray(got[1]), np.asarray(full[1]))


def test_q4_kernels_odd_n_tail_nibble():
    """Odd logical N: the last byte's high nibble is padding and must
    contribute exactly nothing to stats or aggregation."""
    k, n, gs = 3, 2 * CHUNK + 1, 512
    x = _chunky(jax.random.key(16), k, n, block=gs)
    q = transport.quantize(x, "int4", group_size=gs)
    g = jax.random.normal(jax.random.key(17), (n,), jnp.float32)
    w = jax.random.uniform(jax.random.key(18), (k,), jnp.float32)
    got = round_stats.round_stats_q4(q.values, q.scales, g, group_size=gs)
    want = ref.round_stats_q4(q.values, q.scales, g, group_size=gs)
    for gg, ww in zip(got, want):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(ww), rtol=2e-3,
                                   atol=1e-2)
    ya = weighted_agg.weighted_agg_q4(w, q.values, q.scales, n=n,
                                      group_size=gs)
    yw = ref.weighted_agg_q4(w, q.values, q.scales, n=n, group_size=gs)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yw), rtol=2e-3,
                               atol=1e-3)


def test_q4_fuzz_parity_seeded():
    """Seeded fuzz sweep over random (K, N, group_size) tuples — the
    shapes deliberately NOT hand-picked, so layout assumptions that only
    hold at the curated boundary cases fail here."""
    rng = np.random.default_rng(1234)
    pow2 = [2, 4, 8, 16, 32, 64, 128, 256, 512, 1024]
    for _ in range(6):
        k = int(rng.integers(1, 70))
        n = int(rng.integers(1, 3 * CHUNK))
        gs = int(pow2[rng.integers(0, len(pow2))])
        x = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        q = transport.quantize(x, "int4", group_size=gs)
        g = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        got = round_stats.round_stats_q4(q.values, q.scales, g,
                                         group_size=gs)
        want = ref.round_stats_q4(q.values, q.scales, g, group_size=gs)
        for gg, ww, name in zip(got, want, ("dots", "sqnorms", "sqg")):
            np.testing.assert_allclose(
                np.asarray(gg), np.asarray(ww), rtol=2e-3, atol=1e-2,
                err_msg=f"{name} K={k} n={n} gs={gs}")


@pytest.mark.parametrize("k", CHUNK_KS)
def test_bf16_wire_through_plain_kernels(k):
    """bf16 transport has no scales: the plain kernels' in-VMEM astype IS
    the dequant, and out_dtype=f32 must avoid a lossy bf16 round-trip."""
    n = CHUNK + 1
    x = jax.random.normal(jax.random.key(6), (k, n), jnp.float32)
    wire = transport.quantize(x, "bf16").values
    w = jax.random.uniform(jax.random.key(7), (k,), jnp.float32)
    got = weighted_agg.weighted_agg(w, wire, out_dtype=jnp.float32)
    assert got.dtype == jnp.float32
    want = ref.weighted_agg(w, wire.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3,
                               atol=1e-4)


# ------------------------------------------------- end-to-end transports


K = 4


def _toy_problem(K=K, tau=3, B=8, d=12, seed=0):
    rng = np.random.default_rng(seed)
    params = {"w": jnp.zeros((d, 1), jnp.float32),
              "b": jnp.zeros((1,), jnp.float32)}
    X = rng.normal(size=(K, tau, B, d)).astype(np.float32)
    w_true = rng.normal(size=(K, d, 1)).astype(np.float32)
    Y = np.einsum("ktbd,kde->ktbe", X, w_true)

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    return params, loss_fn, (jnp.asarray(X), jnp.asarray(Y))


def _run(engine, transport_name, method="fedadp", rounds=3, k=K, mesh=None,
         error_feedback=False, downlink="f32", group_size=512,
         downlink_error_feedback=False, params=None):
    params0, loss_fn, batches = _toy_problem(K=k)
    params = params0 if params is None else params
    cfg = fl.FLConfig(num_clients=k, clients_per_round=k, local_steps=3,
                      method=method, engine=engine, transport=transport_name,
                      error_feedback=error_feedback, downlink=downlink,
                      group_size=group_size,
                      downlink_error_feedback=downlink_error_feedback,
                      base_lr=0.05)
    rf = jax.jit(fl.make_round_fn(loss_fn, cfg, mesh=mesh))
    st = fl.init_round_state(cfg, params)
    sel = jnp.arange(k, dtype=jnp.int32)
    sizes = jnp.asarray(10.0 * (1.0 + np.arange(k, dtype=np.float32)))
    for r in range(rounds):
        st, m = rf(st, batches, sel, sizes)
    return st.params, st.angle, m, st.ef, st.dl_ef


def _assert_trees_close(a, b, atol=1e-5):
    jax.tree.map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=1e-5, atol=atol), a, b)


@pytest.mark.parametrize("uplink", list(transport.TRANSPORTS))
@pytest.mark.parametrize("downlink", list(transport.DOWNLINKS))
def test_engines_agree_per_wire_pair(uplink, downlink):
    """The acceptance pin: tree (dequantize-then-reference) == flat
    (fused-dequant kernels) == flat_sharded (1-way mesh) to 1e-5 for
    EVERY (uplink, downlink) transport pair, multi-round. int4 runs a
    sub-row scale group (32) so the grouped-dequant path is exercised."""
    gs = 32 if uplink == "int4" else 512
    mesh = jax.make_mesh((1,), ("data",))
    p_t, s_t, m_t, _, _ = _run("tree", uplink, downlink=downlink,
                               group_size=gs)
    p_f, s_f, m_f, _, _ = _run("flat", uplink, downlink=downlink,
                               group_size=gs)
    p_s, s_s, m_s, _, _ = _run("flat_sharded", uplink, downlink=downlink,
                               group_size=gs, mesh=mesh)
    _assert_trees_close(p_t, p_f)
    _assert_trees_close(p_t, p_s)
    np.testing.assert_allclose(s_t.smoothed, s_f.smoothed, atol=1e-5)
    np.testing.assert_allclose(s_t.smoothed, s_s.smoothed, atol=1e-5)
    for m_other in (m_f, m_s):
        np.testing.assert_allclose(np.asarray(m_t["weights"]),
                                   np.asarray(m_other["weights"]),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("transport_name", ["bf16", "int8", "int4"])
def test_quantized_engines_agree_fedavg(transport_name):
    """fedavg's psi-weighted aggregate reuses the stats aggregate in the
    single-region sharded round — pin it per quantized wire too."""
    mesh = jax.make_mesh((1,), ("data",))
    p_t, s_t, m_t, _, _ = _run("tree", transport_name, "fedavg")
    p_f, s_f, m_f, _, _ = _run("flat", transport_name, "fedavg")
    p_s, s_s, m_s, _, _ = _run("flat_sharded", transport_name, "fedavg",
                               mesh=mesh)
    _assert_trees_close(p_t, p_f)
    _assert_trees_close(p_t, p_s)
    np.testing.assert_allclose(s_t.smoothed, s_f.smoothed, atol=1e-5)
    np.testing.assert_allclose(s_t.smoothed, s_s.smoothed, atol=1e-5)


@pytest.mark.parametrize("engine", ["tree", "flat"])
@pytest.mark.parametrize("transport_name", ["int8", "int4"])
def test_quantized_close_to_f32_reference(engine, transport_name):
    """Compression must perturb, not distort: int8/int4 trajectories stay
    near the f32 wire (the convergence-parity pin runs in
    benchmarks/run.py and tests/test_golden_convergence.py). int4's quant
    step is 16x coarser than int8's, so its drift bound scales with it."""
    atol = 5e-3 if transport_name == "int8" else 8e-2
    p_q, s_q, m_q, _, _ = _run(engine, transport_name)
    p_f, s_f, m_f, _, _ = _run(engine, "f32")
    _assert_trees_close(p_q, p_f, atol=atol)
    np.testing.assert_allclose(np.asarray(m_q["theta"]),
                               np.asarray(m_f["theta"]), atol=10 * atol)
    # ... but quantization is genuinely lossy (else this proves nothing)
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(p_q), jax.tree.leaves(p_f)))


def test_quantized_downlink_close_to_f32_broadcast():
    """The compressed broadcast perturbs (clients train from a lossy
    model) but must not distort the trajectory."""
    params = {"w": jnp.full((12, 1), 0.05, jnp.float32),
              "b": jnp.full((1,), 0.01, jnp.float32)}
    p_q, _, m_q, _, _ = _run("flat", "f32", downlink="int8", params=params)
    p_f, _, m_f, _, _ = _run("flat", "f32", downlink="f32", params=params)
    _assert_trees_close(p_q, p_f, atol=2e-2)
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(p_q), jax.tree.leaves(p_f)))


def test_int8_tree_matches_flat_with_bf16_leaves():
    """bf16-leaf model under the int8 wire: the tree engine's dequantized
    reconstruction must stay f32 (a second rounding through the bf16 leaf
    dtype would push the angle stats off the flat engine, which streams
    the wire directly), and the param dtype must survive the round."""
    rng = np.random.default_rng(0)
    d = 12
    X = jnp.asarray(rng.normal(size=(K, 3, 8, d)).astype(np.float32))
    w_true = rng.normal(size=(K, d, 1)).astype(np.float32)
    Y = jnp.asarray(np.einsum("ktbd,kde->ktbe", X, w_true))

    def loss_fn(p, batch):
        x, y = batch
        pred = x @ p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)
        return jnp.mean((pred - y) ** 2)

    outs = {}
    for engine in ("tree", "flat"):
        params = {"w": jnp.zeros((d, 1), jnp.bfloat16),
                  "b": jnp.zeros((1,), jnp.bfloat16)}
        cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                          method="fedadp", engine=engine, transport="int8",
                          base_lr=0.05)
        rf = jax.jit(fl.make_round_fn(loss_fn, cfg))
        st = fl.init_round_state(cfg, params)
        sel = jnp.arange(K, dtype=jnp.int32)
        sizes = jnp.asarray([10.0, 20.0, 30.0, 40.0])
        for r in range(3):
            st, m = rf(st, (X, Y), sel, sizes)
        outs[engine] = (st.params, m)
    for a, b in zip(jax.tree.leaves(outs["tree"][0]),
                    jax.tree.leaves(outs["flat"][0])):
        assert a.dtype == jnp.bfloat16 and b.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-2)
    # stats see identical f32 dequantized values in both engines
    np.testing.assert_allclose(np.asarray(outs["tree"][1]["theta"]),
                               np.asarray(outs["flat"][1]["theta"]),
                               atol=1e-5)


@pytest.mark.parametrize("transport_name", ["int8", "int4"])
@pytest.mark.parametrize("k", [1, 33])
def test_quantized_flat_ragged_k_end_to_end(transport_name, k):
    """Quantized wire + ragged client chunk (tail bounds mask) together.
    K=1 is the int4 packed-width == 1 degenerate case for N odd."""
    p_t, s_t, m_t, _, _ = _run("tree", transport_name, rounds=2, k=k)
    p_f, s_f, m_f, _, _ = _run("flat", transport_name, rounds=2, k=k)
    _assert_trees_close(p_t, p_f)
    np.testing.assert_allclose(np.asarray(m_t["theta"]),
                               np.asarray(m_f["theta"]), atol=1e-5)


# ---------------------------------------------------------- error feedback


@pytest.mark.parametrize("transport_name", ["int8", "int4"])
def test_error_feedback_round1_residual_is_quant_error(transport_name):
    """With zero-initialized EF state, round 1's carried residual must be
    exactly flat(deltas) - dequantize(quantize(flat(deltas)))."""
    params, loss_fn, batches = _toy_problem()
    cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                      method="fedadp", engine="flat",
                      transport=transport_name, error_feedback=True,
                      base_lr=0.05)
    deltas, _ = jax.vmap(
        lambda b: fl.local_update(loss_fn, params, b, cfg.base_lr)
    )(batches)
    flat0, _ = treemath.tree_ravel_stacked(deltas)
    want = np.asarray(flat0 - transport.roundtrip(flat0, transport_name))
    _, _, _, ef, _ = _run("flat", transport_name, rounds=1,
                          error_feedback=True)
    np.testing.assert_allclose(np.asarray(ef), want, atol=1e-7)
    assert np.abs(want).sum() > 0  # quantization actually dropped signal


def test_error_feedback_carries_across_rounds():
    """Round 2 replays round 1's residual into the uplink: the EF
    trajectory must diverge from the uncompensated int8 one, and the
    carried residual stays within the per-chunk quantization bound."""
    p_ef, _, m_ef, ef, _ = _run("flat", "int8", rounds=3,
                                error_feedback=True)
    p_nc, _, m_nc, _, _ = _run("flat", "int8", rounds=3)
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(p_ef), jax.tree.leaves(p_nc)))
    assert np.all(np.isfinite(np.asarray(ef)))
    # residual of a quantized signal is at most half a quant step of the
    # (residual-boosted) signal — loosely, it must not blow up round over
    # round: bound by the largest per-round delta magnitude seen.
    assert np.abs(np.asarray(ef)).max() < 1.0


def test_error_feedback_requires_quantized_transport():
    params, loss_fn, _ = _toy_problem()
    cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                      transport="f32", error_feedback=True)
    with pytest.raises(ValueError, match="error_feedback"):
        fl.make_round_fn(loss_fn, cfg)


def test_error_feedback_requires_state_buffer():
    """A RoundState missing its EF buffer (e.g. built for a config without
    error_feedback) must be refused, not silently run uncompensated."""
    params, loss_fn, batches = _toy_problem()
    cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                      engine="flat", transport="int8", error_feedback=True)
    rf = fl.make_round_fn(loss_fn, cfg)
    st = fl.init_round_state(cfg, params)._replace(ef=None)
    with pytest.raises(ValueError, match="state.ef"):
        rf(st, batches, jnp.arange(K, dtype=jnp.int32), jnp.ones((K,)))


# ------------------------------------------------ downlink error feedback


def _nonzero_params():
    """Downlink tests need non-zero params: an all-zero model compresses
    exactly, leaving nothing for the broadcast EF to carry."""
    return {"w": jnp.full((12, 1), 0.05, jnp.float32),
            "b": jnp.full((1,), 0.01, jnp.float32)}


def test_downlink_ef_round1_residual_is_broadcast_quant_error():
    """With zero-initialized downlink EF state, round 1's carried residual
    must be exactly p - decompress(compress(p)) of the INITIAL params."""
    params = _nonzero_params()
    pvec, _ = treemath.tree_ravel(params)
    want = np.asarray(
        pvec - transport.downlink.broadcast_roundtrip(pvec, "int8"))
    _, _, _, _, dl = _run("flat", "f32", rounds=1, downlink="int8",
                          downlink_error_feedback=True, params=params)
    np.testing.assert_allclose(np.asarray(dl), want, atol=1e-7)
    assert np.abs(want).sum() > 0


def test_downlink_ef_carries_across_rounds():
    """The EF broadcast trajectory diverges from the uncompensated one and
    the carried residual stays bounded."""
    params = _nonzero_params()
    p_ef, _, _, _, dl = _run("flat", "f32", rounds=3, downlink="int8",
                             downlink_error_feedback=True, params=params)
    p_nc, _, _, _, _ = _run("flat", "f32", rounds=3, downlink="int8",
                            params=params)
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(p_ef), jax.tree.leaves(p_nc)))
    assert np.all(np.isfinite(np.asarray(dl)))
    assert np.abs(np.asarray(dl)).max() < 1.0


def test_downlink_ef_engines_agree():
    """The EF broadcast is computed upstream of the engine branch: tree ==
    flat == flat_sharded to 1e-5 under downlink EF + quantized uplink."""
    params = _nonzero_params()
    mesh = jax.make_mesh((1,), ("data",))
    outs = {
        eng: _run(eng, "int4", rounds=3, downlink="int8",
                  downlink_error_feedback=True, params=params,
                  mesh=(mesh if eng == "flat_sharded" else None))
        for eng in ("tree", "flat", "flat_sharded")
    }
    for eng in ("flat", "flat_sharded"):
        _assert_trees_close(outs["tree"][0], outs[eng][0])
        np.testing.assert_allclose(np.asarray(outs["tree"][4]),
                                   np.asarray(outs[eng][4]), atol=1e-6)


def test_downlink_ef_requires_quantized_downlink():
    params, loss_fn, _ = _toy_problem()
    cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                      downlink="f32", downlink_error_feedback=True)
    with pytest.raises(ValueError, match="downlink_error_feedback"):
        fl.make_round_fn(loss_fn, cfg)


def test_downlink_ef_requires_state_buffer():
    params, loss_fn, batches = _toy_problem()
    cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                      engine="flat", downlink="int8",
                      downlink_error_feedback=True)
    rf = fl.make_round_fn(loss_fn, cfg)
    st = fl.init_round_state(cfg, params)._replace(dl_ef=None)
    with pytest.raises(ValueError, match="state.dl_ef"):
        rf(st, batches, jnp.arange(K, dtype=jnp.int32), jnp.ones((K,)))


# ------------------------------------------------------------- validation


def test_unknown_transport_rejected():
    params, loss_fn, _ = _toy_problem()
    cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                      transport="fp8")
    with pytest.raises(ValueError, match="transport"):
        fl.make_round_fn(loss_fn, cfg)


def test_unknown_downlink_rejected():
    """int4 is an uplink-only format: the downlink whitelist must refuse
    it (and anything else outside f32/bf16/int8)."""
    params, loss_fn, _ = _toy_problem()
    for dl in ("int4", "fp8"):
        cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                          downlink=dl)
        with pytest.raises(ValueError, match="downlink"):
            fl.make_round_fn(loss_fn, cfg)


def test_bad_group_size_rejected_at_config():
    params, loss_fn, _ = _toy_problem()
    cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                      transport="int4", group_size=100)
    with pytest.raises(ValueError, match="group_size"):
        fl.make_round_fn(loss_fn, cfg)


def test_sequential_mode_rejects_quantized_transport():
    params, loss_fn, _ = _toy_problem()
    cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                      mode="sequential", transport="int8")
    with pytest.raises(ValueError, match="sequential"):
        fl.make_round_fn(loss_fn, cfg)


def test_sequential_mode_rejects_quantized_downlink():
    params, loss_fn, _ = _toy_problem()
    cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                      mode="sequential", downlink="int8")
    with pytest.raises(ValueError, match="parallel"):
        fl.make_round_fn(loss_fn, cfg)


def test_shard_map_tree_engine_rejects_quantized_transport():
    """The ROADMAP contract: the tree engine never reads quantized buffers;
    fedadp_aggregate must refuse rather than silently dequantize."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import PartitionSpec as P
    with pytest.raises(ValueError, match="tree"):
        fl_shard_map.fedadp_aggregate(mesh, {"a": P("data")}, alpha=5.0,
                                      engine="tree", transport="int8")


def test_shard_map_flat_engine_quantized_matches_f32_loosely():
    """fedadp_aggregate(engine="flat", transport="int8"/"int4") on a 1-way
    mesh: runs end-to-end and stays near the f32 wire (int4's bound scales
    with its 16x coarser step)."""
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    Kk = 4
    deltas = {
        "a": jax.random.normal(jax.random.key(0), (Kk, 8, 6)) * 0.1,
        "b": jax.random.normal(jax.random.key(1), (Kk, 16)) * 0.1,
    }
    pspecs = {"a": P("data"), "b": P("data")}
    sizes = jnp.asarray([10.0, 20.0, 30.0, 40.0])
    sm_prev = jnp.zeros((Kk,))
    cnt_prev = jnp.zeros((Kk,), jnp.int32)
    outs = {}
    for tr in ("f32", "int8", "int4"):
        agg = fl_shard_map.fedadp_aggregate(mesh, pspecs, alpha=5.0,
                                            engine="flat", transport=tr,
                                            group_size=32)
        with mesh:
            outs[tr] = jax.jit(agg)(deltas, sizes, sm_prev, cnt_prev)
    for tr, atol in (("int8", 5e-3), ("int4", 5e-2)):
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=atol),
            outs["f32"][0], outs[tr][0])
        np.testing.assert_allclose(np.asarray(outs["f32"][1]),
                                   np.asarray(outs[tr][1]), atol=10 * atol)


# ---------------------------------------------------------------------------
# 2D (client x model) wire: quantization chunks are SHARD-LOCAL.
# ---------------------------------------------------------------------------

from jax.sharding import PartitionSpec as P  # noqa: E402


def _blocked_wire_fixture(k=3, m=4, seed=0):
    rng = np.random.default_rng(seed)
    stacked = {
        "wq": jnp.asarray(rng.normal(size=(k, 6, 8)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(k, 7)).astype(np.float32)),
    }
    pspecs = {"wq": P(None, "model"), "b": P(None)}
    lay = treemath.blocked_layout(stacked, pspecs, m)
    leaves = jax.tree.leaves(stacked)

    def block(j):
        loc = []
        for x, sdim in zip(leaves, lay.sharded_dims):
            if sdim >= 0:
                step = x.shape[sdim + 1] // m
                sl = [slice(None)] * x.ndim
                sl[sdim + 1] = slice(j * step, (j + 1) * step)
                loc.append(x[tuple(sl)])
            else:
                loc.append(x)
        return treemath.blocked_ravel_local(loc, lay, j)

    return stacked, lay, block


@pytest.mark.parametrize("tr,gs", [("int8", 0), ("int4", 8)])
def test_shard_local_scales_are_locally_determined(tr, gs):
    """The 2D wire contract: each model shard quantizes its OWN (K, N_loc)
    block, so a shard's values and scales depend only on that shard's
    elements — perturbing shard i cannot move shard j's wire bytes (with
    per-shard chunking, a scale can never straddle a model-axis split)."""
    _, lay, block = _blocked_wire_fixture()
    kw = dict(group_size=gs) if gs else {}
    base = [transport.quantize(block(j), tr, **kw) for j in range(4)]
    # perturb shard 0's elements only: scale up wq's first column block
    stacked2, lay2, block2 = _blocked_wire_fixture()
    stacked2["wq"] = stacked2["wq"].at[:, :, :2].mul(100.0)
    leaves2 = jax.tree.leaves(stacked2)

    def blk2(j):
        loc = []
        for x, sdim in zip(leaves2, lay2.sharded_dims):
            if sdim >= 0:
                step = x.shape[sdim + 1] // 4
                sl = [slice(None)] * x.ndim
                sl[sdim + 1] = slice(j * step, (j + 1) * step)
                loc.append(x[tuple(sl)])
            else:
                loc.append(x)
        return treemath.blocked_ravel_local(loc, lay2, j)

    pert = [transport.quantize(blk2(j), tr, **kw) for j in range(4)]
    # shard 0 changed...
    assert not np.array_equal(np.asarray(base[0].values),
                              np.asarray(pert[0].values))
    # ...but every other shard's wire bytes AND scales are untouched
    for j in range(1, 4):
        np.testing.assert_array_equal(np.asarray(base[j].values),
                                      np.asarray(pert[j].values))
        np.testing.assert_array_equal(np.asarray(base[j].scales),
                                      np.asarray(pert[j].scales))


@pytest.mark.parametrize("tr,gs", [("int8", 0), ("int4", 4)])
def test_shard_local_roundtrip_matches_per_block_reference(tr, gs):
    """fl_shard_map's blocked roundtrip == quantize/dequantize each shard's
    block independently with the reference quantizer — pinned without a
    mesh by replaying the per-shard blocks by hand."""
    _, lay, block = _blocked_wire_fixture()
    kw = dict(group_size=gs) if gs else {}
    for j in range(4):
        blk = block(j)
        rt = transport.roundtrip(blk, tr, **kw)
        q = transport.quantize(blk, tr, **kw)
        np.testing.assert_array_equal(np.asarray(rt),
                                      np.asarray(transport.dequantize(q)))
        # per-shard scale columns cover ceil(width/chunk) chunks of THIS
        # block only — the scale count is derived from the LOCAL width
        if tr == "int8":
            assert q.scales.shape == (3, transport.num_chunks(lay.width))
        else:
            assert q.scales.shape == (3, transport.num_groups(lay.width, gs))


def test_shard_local_chunks_differ_from_global_wire():
    """Same logical deltas, different chunk boundaries: the 2D blocked wire
    is NOT byte-identical to the global (1D) wire — that is by design (the
    wire layout is mesh-derived), and exactly why the tree engine on a 2D
    mesh must consume the blocked reconstruction rather than the global
    one. Guards against silently 'simplifying' the tree path back to the
    global quantizer."""
    stacked, lay, block = _blocked_wire_fixture()
    flat, _ = treemath.tree_ravel_stacked(stacked)
    global_rt = np.asarray(transport.roundtrip(flat, "int8"))
    # blocked reconstruction, reassembled into ravel order
    k = flat.shape[0]
    leaves = jax.tree.leaves(stacked)
    recs = {i: [] for i in range(len(leaves))}
    for j in range(4):
        rt = transport.roundtrip(block(j), "int8")
        for i, seg in enumerate(treemath.blocked_split(rt, lay)):
            recs[i].append(seg)
    parts = []
    for i, (shape, sdim) in enumerate(zip(lay.shapes, lay.sharded_dims)):
        if sdim >= 0:
            step = shape[sdim] // 4
            local = list(shape)
            local[sdim] = step
            rec = jnp.concatenate(
                [s.reshape((k,) + tuple(local)) for s in recs[i]],
                axis=sdim + 1)
        else:
            size = int(np.prod(shape)) if shape else 1
            rec = jnp.concatenate(recs[i], axis=1)[:, :size].reshape(
                (k,) + shape)
        parts.append(np.asarray(rec).reshape(k, -1))
    blocked_rt = np.concatenate(parts, axis=1)
    # both are valid int8 reconstructions (same error envelope)...
    assert np.max(np.abs(blocked_rt - np.asarray(flat))) < 0.1
    assert np.max(np.abs(global_rt - np.asarray(flat))) < 0.1
    # ...but they are different wires (different chunk boundaries)
    assert not np.array_equal(blocked_rt, global_rt)
