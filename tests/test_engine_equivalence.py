"""Flat-buffer vs tree round-engine equivalence (the engine="flat" contract).

The tree engine is the reference implementation; the flat engine re-routes
the identical round math through `tree_ravel_stacked` + the fused Pallas
kernels (`round_stats`, `weighted_agg`), now chunked over the client axis
so ANY K is served (no MAX_K ceiling). Multi-round trajectories must agree
to 1e-5 for both methods, with and without the MoE angle filter, for K
across chunk boundaries (1, 33, 64), and the parallel engines must agree
with the sequential scan under full participation. The client-sharded
variant (engine="flat_sharded") is pinned against both on an 8-way
host-device mesh in a subprocess.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fl

K = 4


def _toy_problem(K=K, tau=3, B=8, d=12, seed=0):
    """Non-IID linear-regression clients, plus a rank-4 'ffn/w_gate' leaf so
    angle_filter="dense_only" (moe_dense_only_pred) actually drops a segment
    of the flat buffer."""
    rng = np.random.default_rng(seed)
    params = {
        "w": jnp.zeros((d, 1), jnp.float32),
        "b": jnp.zeros((1,), jnp.float32),
        "ffn": {"w_gate": jnp.full((1, 1, 4, 4), 0.1, jnp.float32)},
    }
    X = rng.normal(size=(K, tau, B, d)).astype(np.float32)
    w_true = rng.normal(size=(K, d, 1)).astype(np.float32)
    Y = np.einsum("ktbd,kde->ktbe", X, w_true)

    def loss_fn(p, batch):
        x, y = batch
        pred = x @ p["w"] + p["b"] + jnp.sum(p["ffn"]["w_gate"] ** 2)
        return jnp.mean((pred - y) ** 2)

    return params, loss_fn, (jnp.asarray(X), jnp.asarray(Y))


def _run(engine, method, angle_filter="all", mode="parallel", rounds=4,
         seed=0, k=K):
    params, loss_fn, batches = _toy_problem(K=k, seed=seed)
    cfg = fl.FLConfig(num_clients=k, clients_per_round=k, local_steps=3,
                      method=method, mode=mode, engine=engine,
                      angle_filter=angle_filter, base_lr=0.05)
    rf = jax.jit(fl.make_round_fn(loss_fn, cfg))
    st = fl.init_round_state(cfg, params)
    sel = jnp.arange(k, dtype=jnp.int32)
    sizes = jnp.asarray(10.0 * (1.0 + np.arange(k, dtype=np.float32)))
    ms = []
    for r in range(rounds):
        st, m = rf(st, batches, sel, sizes)
        ms.append(m)
    return st.params, st.angle, ms


def _assert_trees_close(a, b, atol=1e-5):
    jax.tree.map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=1e-5, atol=atol),
        a, b,
    )


@pytest.mark.parametrize("angle_filter", ["all", "dense_only"])
@pytest.mark.parametrize("method", ["fedadp", "fedavg"])
def test_flat_matches_tree_multi_round(method, angle_filter):
    p_t, s_t, m_t = _run("tree", method, angle_filter)
    p_f, s_f, m_f = _run("flat", method, angle_filter)
    _assert_trees_close(p_t, p_f)
    np.testing.assert_allclose(s_t.smoothed, s_f.smoothed, atol=1e-5)
    assert s_t.count.tolist() == s_f.count.tolist()
    for mt, mf in zip(m_t, m_f):
        for key in ("theta", "theta_smoothed", "weights", "divergence",
                    "loss", "cos", "expected_contribution"):
            np.testing.assert_allclose(
                np.asarray(mt[key]), np.asarray(mf[key]), rtol=1e-5,
                atol=1e-5, err_msg=f"metric {key}")


@pytest.mark.parametrize("method", ["fedadp", "fedavg"])
def test_flat_matches_tree_bf16(method):
    """bf16 params: both engines compute angle stats from the UNROUNDED f32
    global delta, so trajectories agree to bf16 resolution (params are
    rounded to bf16 each round, so exact 1e-5 equality is a f32-only
    contract), and param dtype survives the round trip."""
    rng = np.random.default_rng(0)
    d = 12
    X = jnp.asarray(rng.normal(size=(K, 3, 8, d)).astype(np.float32))
    w_true = rng.normal(size=(K, d, 1)).astype(np.float32)
    Y = jnp.asarray(np.einsum("ktbd,kde->ktbe", X, w_true))

    def loss_fn(p, batch):
        x, y = batch
        pred = x @ p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)
        return jnp.mean((pred - y) ** 2)

    outs = {}
    for engine in ("tree", "flat"):
        params = {"w": jnp.zeros((d, 1), jnp.bfloat16),
                  "b": jnp.zeros((1,), jnp.bfloat16)}
        cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                          method=method, engine=engine, base_lr=0.05)
        rf = jax.jit(fl.make_round_fn(loss_fn, cfg))
        st = fl.init_round_state(cfg, params)
        sel = jnp.arange(K, dtype=jnp.int32)
        sizes = jnp.asarray([10.0, 20.0, 30.0, 40.0])
        for r in range(3):
            st, m = rf(st, (X, Y), sel, sizes)
        outs[engine] = (st.params, m)
    for a, b in zip(jax.tree.leaves(outs["tree"][0]),
                    jax.tree.leaves(outs["flat"][0])):
        assert a.dtype == jnp.bfloat16 and b.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-2)
    np.testing.assert_allclose(np.asarray(outs["tree"][1]["theta"]),
                               np.asarray(outs["flat"][1]["theta"]),
                               atol=1e-2)


def test_dense_only_filter_changes_angles_in_both_engines():
    """The segment mask must actually bite (w_gate deltas are nonzero), and
    it must bite identically in both engines."""
    for engine in ("tree", "flat"):
        _, _, m_all = _run(engine, "fedadp", "all")
        _, _, m_dense = _run(engine, "fedadp", "dense_only")
        assert not np.allclose(np.asarray(m_all[-1]["theta"]),
                               np.asarray(m_dense[-1]["theta"])), engine


@pytest.mark.parametrize("engine", ["tree", "flat"])
def test_parallel_engine_matches_sequential(engine):
    """Under full participation both parallel engines implement the same
    math as the sequential two-pass scan."""
    p_par, s_par, m_par = _run(engine, "fedadp", mode="parallel")
    p_seq, s_seq, m_seq = _run("tree", "fedadp", mode="sequential")
    _assert_trees_close(p_par, p_seq, atol=2e-5)
    np.testing.assert_allclose(s_par.smoothed, s_seq.smoothed, rtol=2e-4)
    np.testing.assert_allclose(m_par[-1]["weights"], m_seq[-1]["weights"],
                               rtol=2e-4)


def test_flat_engine_requires_parallel_mode():
    params, loss_fn, _ = _toy_problem()
    cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                      mode="sequential", engine="flat")
    with pytest.raises(ValueError, match="flat"):
        fl.make_round_fn(loss_fn, cfg)


@pytest.mark.parametrize("k", [1, 33, 64])
def test_flat_engine_unbounded_k(k):
    """Regression for the former MAX_K=32 trace-time error: the chunked
    kernels serve any K — K=1 (degenerate chunk), K=33 (ragged chunk), and
    K=64 (multiple full chunks) must all match the tree reference."""
    p_t, s_t, m_t = _run("tree", "fedadp", rounds=2, k=k)
    p_f, s_f, m_f = _run("flat", "fedadp", rounds=2, k=k)
    _assert_trees_close(p_t, p_f)
    np.testing.assert_allclose(s_t.smoothed, s_f.smoothed, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(m_t[-1]["weights"]), np.asarray(m_f[-1]["weights"]),
        rtol=1e-5, atol=1e-5)


def test_flat_engine_k128():
    """Acceptance: FLConfig(engine="flat") works for K=128 (one round)."""
    p_t, _, m_t = _run("tree", "fedadp", rounds=1, k=128)
    p_f, _, m_f = _run("flat", "fedadp", rounds=1, k=128)
    _assert_trees_close(p_t, p_f)
    np.testing.assert_allclose(
        np.asarray(m_t[0]["theta"]), np.asarray(m_f[0]["theta"]), atol=1e-5)


def test_flat_sharded_requires_mesh():
    params, loss_fn, _ = _toy_problem()
    cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                      engine="flat_sharded")
    with pytest.raises(ValueError, match="mesh"):
        fl.make_round_fn(loss_fn, cfg)


def test_flat_sharded_nondivisible_k_matches_tree_subprocess():
    """K % shards != 0 no longer raises: the client axis is zero-padded
    before sharding (padded rows carry zero deltas and zero data size, so
    they get exactly zero weight and zero stats). K=13 on an 8-way mesh is
    pinned against the tree engine, for the f32, int8 and packed-int4
    wires — the int4 leg under a quantized (int8) downlink, so sharded
    parity is exercised with BOTH directions of the wire compressed."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import fl
        K, d, tau, B = 13, 12, 2, 4
        rng = np.random.default_rng(0)
        params = {"w": jnp.full((d, 1), 0.05, jnp.float32),
                  "b": jnp.zeros((1,), jnp.float32)}
        X = jnp.asarray(rng.normal(size=(K, tau, B, d)).astype(np.float32))
        wt = rng.normal(size=(K, d, 1)).astype(np.float32)
        Y = jnp.asarray(np.einsum("ktbd,kde->ktbe", X, wt))
        def loss_fn(p, batch):
            x, y = batch
            return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)
        mesh = jax.make_mesh((8,), ("data",))
        sel = jnp.arange(K, dtype=jnp.int32)
        sizes = jnp.asarray(np.linspace(10.0, 40.0, K, dtype=np.float32))
        for tr, dl in (("f32", "f32"), ("int8", "f32"), ("int4", "int8")):
            outs = {}
            for engine in ("tree", "flat_sharded"):
                cfg = fl.FLConfig(num_clients=K, clients_per_round=K,
                                  local_steps=tau, method="fedadp",
                                  engine=engine, transport=tr, downlink=dl,
                                  group_size=32, base_lr=0.05)
                rf = jax.jit(fl.make_round_fn(loss_fn, cfg, mesh=mesh))
                st = fl.init_round_state(cfg, params)
                with mesh:
                    for r in range(2):
                        st, m = rf(st, (X, Y), sel, sizes)
                outs[engine] = (st.params, m)
            jax.tree.map(lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5),
                outs["tree"][0], outs["flat_sharded"][0])
            np.testing.assert_allclose(
                np.asarray(outs["tree"][1]["weights"]),
                np.asarray(outs["flat_sharded"][1]["weights"]),
                rtol=1e-5, atol=1e-6)
        print("RAGGED_SHARD_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "RAGGED_SHARD_OK" in out.stdout, out.stderr[-2000:]


def test_flat_sharded_single_device_matches_flat():
    """On a 1-way client mesh the sharded flat engine is the flat engine
    plus no-op psums; trajectories must agree to 1e-5."""
    params, loss_fn, batches = _toy_problem()
    mesh = jax.make_mesh((1,), ("data",))
    outs = {}
    for engine in ("flat", "flat_sharded"):
        cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                          method="fedadp", engine=engine, base_lr=0.05)
        rf = jax.jit(fl.make_round_fn(loss_fn, cfg, mesh=mesh))
        st = fl.init_round_state(cfg, params)
        sel = jnp.arange(K, dtype=jnp.int32)
        sizes = jnp.asarray([10.0, 20.0, 30.0, 40.0])
        for r in range(3):
            st, m = rf(st, batches, sel, sizes)
        outs[engine] = (st.params, st.angle, m)
    _assert_trees_close(outs["flat"][0], outs["flat_sharded"][0])
    np.testing.assert_allclose(outs["flat"][1].smoothed,
                               outs["flat_sharded"][1].smoothed, atol=1e-5)
    np.testing.assert_allclose(np.asarray(outs["flat"][2]["weights"]),
                               np.asarray(outs["flat_sharded"][2]["weights"]),
                               rtol=1e-5, atol=1e-6)


def test_flat_sharded_matches_tree_8way_subprocess():
    """Acceptance pin: sharded-flat == flat == tree to 1e-5 over multi-round
    runs on an 8-way host-device client mesh (subprocess — this session is
    pinned to one device)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import fl
        K, d, tau, B = 16, 12, 3, 8
        rng = np.random.default_rng(0)
        params = {"w": jnp.zeros((d, 1), jnp.float32),
                  "b": jnp.zeros((1,), jnp.float32)}
        X = jnp.asarray(rng.normal(size=(K, tau, B, d)).astype(np.float32))
        wt = rng.normal(size=(K, d, 1)).astype(np.float32)
        Y = jnp.asarray(np.einsum("ktbd,kde->ktbe", X, wt))
        def loss_fn(p, batch):
            x, y = batch
            return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)
        mesh = jax.make_mesh((8,), ("data",))
        sel = jnp.arange(K, dtype=jnp.int32)
        sizes = jnp.asarray(np.linspace(10.0, 40.0, K, dtype=np.float32))
        outs = {}
        for engine in ("tree", "flat", "flat_sharded"):
            cfg = fl.FLConfig(num_clients=K, clients_per_round=K,
                              local_steps=tau, method="fedadp",
                              engine=engine, base_lr=0.05)
            rf = jax.jit(fl.make_round_fn(loss_fn, cfg, mesh=mesh))
            st = fl.init_round_state(cfg, params)
            with mesh:
                for r in range(3):
                    st, m = rf(st, (X, Y), sel, sizes)
            outs[engine] = (st.params, st.angle, m)
        for engine in ("flat", "flat_sharded"):
            jax.tree.map(lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5),
                outs["tree"][0], outs[engine][0])
            np.testing.assert_allclose(outs["tree"][1].smoothed,
                                       outs[engine][1].smoothed, atol=1e-5)
            np.testing.assert_allclose(np.asarray(outs["tree"][2]["weights"]),
                                       np.asarray(outs[engine][2]["weights"]),
                                       rtol=1e-5, atol=1e-6)
        print("SHARDED_FLAT_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARDED_FLAT_OK" in out.stdout, out.stderr[-2000:]


def test_unknown_engine_rejected():
    params, loss_fn, _ = _toy_problem()
    cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                      engine="nope")
    with pytest.raises(ValueError, match="engine"):
        fl.make_round_fn(loss_fn, cfg)


def test_unknown_angle_filter_rejected():
    """A typo'd filter must not silently run with unfiltered stats."""
    params, loss_fn, _ = _toy_problem()
    cfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=3,
                      angle_filter="dense-only")
    with pytest.raises(ValueError, match="angle_filter"):
        fl.make_round_fn(loss_fn, cfg)


def test_flat_engine_subset_selection():
    """Subset participation: angle-state slots update identically."""
    params, loss_fn, batches = _toy_problem()
    outs = {}
    for engine in ("tree", "flat"):
        cfg = fl.FLConfig(num_clients=8, clients_per_round=K, local_steps=3,
                          method="fedadp", engine=engine, base_lr=0.05)
        rf = jax.jit(fl.make_round_fn(loss_fn, cfg))
        sel = jnp.asarray([1, 3, 5, 7], jnp.int32)
        st, _ = rf(fl.init_round_state(cfg, params), batches, sel,
                   jnp.ones((K,)))
        outs[engine] = (st.params, st.angle)
    _assert_trees_close(outs["tree"][0], outs["flat"][0])
    np.testing.assert_allclose(outs["tree"][1].smoothed,
                               outs["flat"][1].smoothed, atol=1e-5)
    assert outs["flat"][1].count.tolist() == [0, 1, 0, 1, 0, 1, 0, 1]


def test_flat_sharded_2d_mesh_matches_tree_subprocess():
    """Tentpole acceptance: the flat engine on 2D (client x model) meshes —
    (2,4) and (4,2) over 8 host devices — matches the tree engine on the
    SAME mesh to 1e-5 for all four uplink transports, with K=6 pinning the
    non-divisible client-axis padding on the (4,2) leg. For the elementwise
    wires (f32/bf16) the trajectory additionally matches the unsharded 1D
    flat engine; the int8/int4 wires are mesh-derived (shard-local scale
    chunks), so their cross-mesh identity is intentionally NOT pinned —
    tree-on-the-same-mesh is the reference (it consumes the identical
    blocked wire through fl_shard_map.make_blocked_roundtrip)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import fl
        K, d, h, tau, B = 6, 12, 8, 2, 4
        rng = np.random.default_rng(0)
        params = {"wq": jnp.asarray(rng.normal(size=(d, h)).astype(np.float32) * 0.1),
                  "w_down": jnp.asarray(rng.normal(size=(h, 1)).astype(np.float32) * 0.1),
                  "b": jnp.zeros((1,), jnp.float32),
                  "scale": jnp.full((5,), 0.3, jnp.float32)}
        X = jnp.asarray(rng.normal(size=(K, tau, B, d)).astype(np.float32))
        wt = rng.normal(size=(K, d, 1)).astype(np.float32)
        Y = jnp.asarray(np.einsum("ktbd,kde->ktbe", X, wt))
        def loss_fn(p, batch):
            x, y = batch
            pred = (x @ p["wq"]) @ p["w_down"] + p["b"] + jnp.sum(p["scale"] ** 2)
            return jnp.mean((pred - y) ** 2)
        sel = jnp.arange(K, dtype=jnp.int32)
        sizes = jnp.asarray(np.linspace(10.0, 40.0, K, dtype=np.float32))
        def leafcmp(a, b, atol, msg):
            for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                np.testing.assert_allclose(
                    np.asarray(la, np.float32), np.asarray(lb, np.float32),
                    rtol=1e-5, atol=atol, err_msg=msg)
        def run(engine, mesh, tr):
            cfg = fl.FLConfig(num_clients=K, clients_per_round=K,
                              local_steps=tau, method="fedadp", engine=engine,
                              transport=tr, group_size=8, base_lr=0.05)
            rf = jax.jit(fl.make_round_fn(loss_fn, cfg, mesh=mesh))
            st = fl.init_round_state(cfg, params)
            import contextlib
            ctx = mesh if mesh is not None else contextlib.nullcontext()
            with ctx:
                for r in range(2):
                    st, m = rf(st, (X, Y), sel, sizes)
            return st, m
        for shape in ((2, 4), (4, 2)):
            mesh = jax.make_mesh(shape, ("data", "model"))
            for tr in ("f32", "bf16", "int8", "int4"):
                st_t, m_t = run("tree", mesh, tr)
                st_f, m_f = run("flat_sharded", mesh, tr)
                leafcmp(st_t.params, st_f.params, 1e-5,
                        f"params {shape} {tr}")
                np.testing.assert_allclose(
                    np.asarray(st_t.angle.smoothed),
                    np.asarray(st_f.angle.smoothed), atol=1e-5)
                np.testing.assert_allclose(
                    np.asarray(m_t["weights"]), np.asarray(m_f["weights"]),
                    rtol=1e-5, atol=1e-6, err_msg=f"weights {shape} {tr}")
                if tr in ("f32", "bf16"):
                    # elementwise wire: identical to the 1D flat engine too
                    st_1, m_1 = run("flat", None, tr)
                    leafcmp(st_1.params, st_f.params, 1e-5,
                            f"1d-vs-2d {shape} {tr}")
                    np.testing.assert_allclose(
                        np.asarray(m_1["weights"]),
                        np.asarray(m_f["weights"]), rtol=1e-5, atol=1e-6)
        print("MESH2D_EQUIV_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "MESH2D_EQUIV_OK" in out.stdout, out.stderr[-2000:]


def test_flat_sharded_2d_keeps_sharded_leaves_sharded():
    """No-gather acceptance: lower the 2D round region alone with sharded
    inputs and assert (a) the aggregated outputs RETAIN the model-axis
    sharding of their param specs, and (b) the compiled module contains no
    all-gather as large as a full model-sharded leaf — the blocked ravel
    is what buys this, so a regression to full-width raveling shows up as
    a big gather here. (Replicated leaves legitimately re-join via O(leaf)
    gathers of their column slices; the threshold only bounds gathers at
    the SHARDED leaf's full stacked size.)"""
    prog = textwrap.dedent("""
        import os, re
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import fl_shard_map
        from repro.models import sharding as msharding
        K, d, h = 8, 8, 256
        rng = np.random.default_rng(0)
        params = {"wq": jnp.zeros((d, h), jnp.float32),
                  "b": jnp.zeros((7,), jnp.float32)}
        deltas = {"wq": jnp.asarray(rng.normal(size=(K, d, h)).astype(np.float32)),
                  "b": jnp.asarray(rng.normal(size=(K, 7)).astype(np.float32))}
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        pspecs = msharding.param_pspecs(params, mesh)
        assert "model" in str(pspecs["wq"]), pspecs
        stacked = jax.tree.map(lambda s: P("data", *tuple(s)), pspecs,
                               is_leaf=lambda x: isinstance(x, P))
        deltas = jax.tree.map(
            lambda l, s: jax.device_put(l, NamedSharding(mesh, s)),
            deltas, stacked)
        psi = jnp.full((K,), 1.0 / K, jnp.float32)
        z = jnp.zeros((K,), jnp.float32)
        sizes = jnp.ones((K,), jnp.float32)
        op = fl_shard_map.make_round_ops_2d(
            mesh, deltas, pspecs, alpha=5.0, transport="int8")
        jop = jax.jit(op)
        g, dots, sqs, sqg, delta, theta, tsm, w = jop(deltas, psi, z, z, sizes)
        # (a) output sharding retains the model axis on the sharded leaf
        assert "model" in str(g["wq"].sharding.spec), g["wq"].sharding
        assert "model" in str(delta["wq"].sharding.spec), delta["wq"].sharding
        # (b) compiled HLO: no all-gather at the sharded leaf's full size
        hlo = jop.lower(deltas, psi, z, z, sizes).compile().as_text()
        full = K * d * h  # stacked wq elements (the thing we must not gather)
        biggest = 0
        for m in re.finditer(r"all-gather[^=]*=?[^f\\n]*f32\\[([0-9,]+)\\]", hlo):
            dims = [int(x) for x in m.group(1).split(",") if x]
            n = int(np.prod(dims)) if dims else 1
            biggest = max(biggest, n)
        assert biggest < d * h, (biggest, d * h)
        # sanity: the module is genuinely partitioned (psums present)
        assert "all-reduce" in hlo
        print("MESH2D_NOGATHER_OK", biggest)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "MESH2D_NOGATHER_OK" in out.stdout, out.stderr[-2000:]
