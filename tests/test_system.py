"""End-to-end behaviour tests: the paper's headline claim on the synthetic
task, transformer FL rounds, and checkpoint round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt
from repro.configs import registry
from repro.core import fl
from repro.core.server import FedServer
from repro.data import synthetic
from repro.models import transformer


@pytest.fixture(scope="module")
def image_task():
    return synthetic.make_image_task(seed=0, num_train=12000, num_test=2000)


def test_fedadp_beats_fedavg_on_noniid(image_task):
    """Paper Table I (qualitative): with 5 IID + 5 one-class non-IID nodes,
    FedAdp reaches the accuracy target in fewer rounds than FedAvg."""
    train, test = image_task
    nodes = synthetic.make_federated(
        train, [("iid", None)] * 5 + [("xclass", 1)] * 5,
        samples_per_node=600, seed=1,
    )
    rounds_to = {}
    for method in ("fedavg", "fedadp"):
        cfg = fl.FLConfig(num_clients=10, clients_per_round=10, local_steps=12,
                          method=method, base_lr=0.05)
        server = FedServer("mlr", cfg, nodes, test, batch_size=50, seed=0)
        hist = server.run(rounds=40, target_acc=0.85, eval_every=2)
        rounds_to[method] = hist.rounds_to_target or 999
    assert rounds_to["fedadp"] < rounds_to["fedavg"], rounds_to


def test_fedadp_reduces_divergence(image_task):
    """Paper Fig. 7: FedAdp lowers cross-client gradient divergence."""
    train, test = image_task
    nodes = synthetic.make_federated(
        train, [("iid", None)] * 3 + [("xclass", 1)] * 3,
        samples_per_node=300, seed=2,
    )
    div = {}
    for method in ("fedavg", "fedadp"):
        cfg = fl.FLConfig(num_clients=6, clients_per_round=6, local_steps=6,
                          method=method, base_lr=0.05)
        server = FedServer("mlr", cfg, nodes, test, batch_size=50, seed=0)
        hist = server.run(rounds=15)
        div[method] = np.mean(hist.divergence[5:])
    assert div["fedadp"] < div["fedavg"], div


def test_transformer_fl_round_parallel():
    """One federated round over a reduced LM arch with non-IID token data."""
    cfg = registry.smoke("gemma-2b")
    params = transformer.init_params(jax.random.key(0), cfg)
    K, tau, B, T = 4, 2, 2, 32
    toks = synthetic.lm_token_batches(0, K, tau * B, T, cfg.vocab_size)
    batches = {"tokens": jnp.asarray(toks.reshape(K, tau, B, T))}
    flcfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=tau,
                        method="fedadp", base_lr=0.1)
    rf = jax.jit(fl.make_round_fn(
        lambda p, b: transformer.loss_fn(p, cfg, b), flcfg))
    st, m = rf(fl.init_round_state(flcfg, params), batches,
               jnp.arange(K, dtype=jnp.int32), jnp.ones((K,)))
    p1 = st.params
    assert jnp.isfinite(m["loss"])
    w = np.asarray(m["weights"])
    assert abs(w.sum() - 1) < 1e-5
    # params actually changed
    diff = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(params)))
    assert diff > 0


def test_transformer_fl_loss_decreases():
    cfg = registry.smoke("gemma-2b")
    params = transformer.init_params(jax.random.key(0), cfg)
    K, tau, B, T = 2, 4, 4, 32
    toks = synthetic.lm_token_batches(1, K, tau * B, T, cfg.vocab_size,
                                      zipf_a=1.6)
    batches = {"tokens": jnp.asarray(toks.reshape(K, tau, B, T))}
    # base_lr=0.3 diverges to NaN on current jax CPU builds; 0.05 trains
    flcfg = fl.FLConfig(num_clients=K, clients_per_round=K, local_steps=tau,
                        method="fedadp", base_lr=0.05, lr_decay=1.0)
    rf = jax.jit(fl.make_round_fn(
        lambda p, b: transformer.loss_fn(p, cfg, b), flcfg))
    st = fl.init_round_state(flcfg, params)
    losses = []
    for r in range(8):
        st, m = rf(st, batches, jnp.arange(K, dtype=jnp.int32),
                   jnp.ones((K,)))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses


def test_checkpoint_roundtrip(tmp_path):
    cfg = registry.smoke("qwen2-vl-2b")
    params = transformer.init_params(jax.random.key(3), cfg)
    path = str(tmp_path / "ckpt.npz")
    ckpt.save(path, {"params": params, "round": jnp.int32(7)})
    back = ckpt.load(path)
    assert int(back["round"]) == 7
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a, np.float32),
                                                   np.asarray(b, np.float32)),
        params, back["params"],
    )


def test_server_checkpoint_state_dict(tmp_path):
    train, test = synthetic.make_image_task(seed=0, num_train=2000, num_test=200)
    nodes = synthetic.make_federated(train, [("iid", None)] * 2,
                                     samples_per_node=100, seed=0)
    cfg = fl.FLConfig(num_clients=2, clients_per_round=2, local_steps=2,
                      method="fedadp")
    s = FedServer("mlr", cfg, nodes, test, batch_size=50)
    s.step()
    path = str(tmp_path / "server.npz")
    ckpt.save(path, {
        "params": s.params,
        "angles": {"smoothed": s.angle_state.smoothed, "count": s.angle_state.count},
        "round": jnp.int32(s.round),
    })
    back = ckpt.load(path)
    assert int(back["round"]) == 1
    np.testing.assert_allclose(back["angles"]["smoothed"], s.angle_state.smoothed)
