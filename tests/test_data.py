"""Data-pipeline properties: partition protocols and learnability."""
import numpy as np
import pytest

from repro.data import synthetic


@pytest.fixture(scope="module")
def task():
    return synthetic.make_image_task(seed=0, num_train=8000, num_test=1000)


def test_shapes_and_range(task):
    train, test = task
    assert train.x.shape == (8000, 28, 28, 1)
    assert train.x.min() >= 0.0 and train.x.max() <= 1.0
    assert set(np.unique(train.y)) == set(range(10))


def test_xclass_partition_has_x_classes(task):
    train, _ = task
    rng = np.random.default_rng(0)
    for x in (1, 2, 3):
        node = synthetic.partition_xclass(rng, train, x, 600)
        assert len(np.unique(node.y)) <= x
        assert len(node.y) == 600


def test_iid_partition_covers_classes(task):
    train, _ = task
    node = synthetic.partition_iid(np.random.default_rng(0), train, 600)
    assert len(np.unique(node.y)) == 10


def test_dirichlet_partition_sizes(task):
    train, _ = task
    nodes = synthetic.dirichlet_partition(
        np.random.default_rng(0), train, 5, 0.5, 200
    )
    assert len(nodes) == 5
    assert all(len(n.y) == 200 for n in nodes)


def test_centrally_learnable(task):
    """MLR on pooled data reaches high accuracy — the FL targets are
    attainable, so rounds-to-target comparisons are meaningful."""
    import jax
    import jax.numpy as jnp

    from repro.models import small

    train, test = task
    params = small.mlr_init(jax.random.key(0))

    @jax.jit
    def step(p, x, y, lr):
        g = jax.grad(lambda q: small.classification_loss(small.mlr_apply, q, x, y))(p)
        return jax.tree.map(lambda a, b: a - lr * b, p, g)

    for e in range(12):
        for i in range(0, 8000, 128):
            params = step(params, jnp.asarray(train.x[i:i+128]),
                          jnp.asarray(train.y[i:i+128]), 0.1)
    acc = small.accuracy(small.mlr_apply, params, test.x, test.y)
    assert acc > 0.9, acc


def test_lm_tokens_noniid_skew():
    toks = synthetic.lm_token_batches(0, 4, 8, 64, 100)
    assert toks.shape == (4, 8, 64)
    # different clients favour different tokens
    top = [np.bincount(toks[i].ravel(), minlength=100).argmax() for i in range(4)]
    assert len(set(top)) > 1


def test_batch_iterator_epochs():
    ds = synthetic.Dataset(np.arange(40, dtype=np.float32).reshape(10, 2, 2, 1),
                           np.arange(10, dtype=np.int32))
    it = synthetic.batch_iterator(ds, 3, seed=0)
    xs, ys = next(it)
    assert xs.shape == (3, 2, 2, 1) and ys.shape == (3,)
