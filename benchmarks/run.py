"""Benchmark driver — one function per paper table/figure, plus kernel
micro-benchmarks and the roofline post-processor.

Prints ``name,us_per_call,derived`` CSV lines. `us_per_call` is the wall
time per federated round (or per kernel call); `derived` is the
table/figure quantity (rounds-to-target, accuracy, divergence ratio, ...).

Usage:  PYTHONPATH=src python -m benchmarks.run [--full] [--tiny] [--only NAME]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit, get_task, node_spec, run_fl


def table1_rounds(full: bool = False) -> None:
    """Paper Table I: rounds to target accuracy, FedAdp vs FedAvg, per
    heterogeneity setting (x-class non-IID)."""
    settings = [("5iid+5non1", node_spec(5, 5, 1)), ("3iid+7non2", node_spec(3, 7, 2))]
    if full:
        settings += [
            ("3iid+7non1", node_spec(3, 7, 1)),
            ("6iid+4non1", node_spec(6, 4, 1)),
            ("5iid+5non2", node_spec(5, 5, 2)),
            ("6iid+4non2", node_spec(6, 4, 2)),
        ]
    rounds = 120 if full else 60
    for name, spec in settings:
        per = {}
        for method in ("fedavg", "fedadp"):
            hist, spr = run_fl(method, spec, rounds=rounds, target=0.85)
            r = hist.rounds_to_target or f">{rounds}"
            per[method] = r
            emit(f"table1/{name}/{method}", spr * 1e6, r)
        if isinstance(per["fedadp"], int) and isinstance(per["fedavg"], int):
            red = 100.0 * (1 - per["fedadp"] / per["fedavg"])
            emit(f"table1/{name}/reduction_pct", 0.0, f"{red:.1f}")


def fig1_noniid_impact(full: bool = False) -> None:
    """Paper Fig. 1: non-IID participation slows FedAvg convergence."""
    for name, spec in [
        ("10iid", node_spec(10, 0, 1)),
        ("5iid+5non1", node_spec(5, 5, 1)),
        ("3iid+7non1", node_spec(3, 7, 1)),
        ("3iid+7non2", node_spec(3, 7, 2)),
    ]:
        hist, spr = run_fl("fedavg", spec, rounds=30, target=None)
        emit(f"fig1/fedavg/{name}/acc@30", spr * 1e6, f"{hist.final_accuracy:.4f}")


def fig5_general_heterogeneity(full: bool = False) -> None:
    """Paper Fig. 5: general (random x_i) heterogeneity, no pure-IID nodes."""
    rng = np.random.default_rng(0)
    case1 = [("xclass", int(x)) for x in rng.permutation(np.arange(1, 11))]
    lo = [("xclass", int(x)) for x in rng.integers(1, 6, 5)]
    hi = [("xclass", int(x)) for x in rng.integers(6, 11, 5)]
    for cname, spec in [("case1", case1), ("case2", lo + hi)]:
        for method in ("fedavg", "fedadp"):
            hist, spr = run_fl(method, spec, rounds=40, target=None)
            emit(
                f"fig5/{cname}/{method}/acc@40",
                spr * 1e6,
                f"{hist.final_accuracy:.4f}",
            )


def fig6_alpha_sweep(full: bool = False) -> None:
    """Paper Fig. 6: effect of the Gompertz alpha (best ~5)."""
    alphas = (1, 2, 5, 7, 10) if full else (2, 5, 10)
    for alpha in alphas:
        hist, spr = run_fl(
            "fedadp",
            node_spec(5, 5, 1),
            rounds=30,
            target=None,
            alpha=float(alpha),
        )
        emit(f"fig6/alpha={alpha}/acc@30", spr * 1e6, f"{hist.final_accuracy:.4f}")


def fig7_divergence(full: bool = False) -> None:
    """Paper Fig. 7: FedAdp shrinks cross-client gradient divergence."""
    div = {}
    for method in ("fedavg", "fedadp"):
        hist, spr = run_fl(method, node_spec(5, 5, 1), rounds=25, target=None)
        div[method] = float(np.mean(hist.divergence[5:]))
        emit(f"fig7/{method}/divergence", spr * 1e6, f"{div[method]:.4f}")
    emit("fig7/ratio_adp_over_avg", 0.0, f"{div['fedadp']/div['fedavg']:.3f}")


def method_ablation(full: bool = False) -> None:
    """Beyond-paper ablation: FedAvg vs FedProx (mu=0.1) vs FedAdp on the
    5 IID + 5 one-class split (rounds to 85%)."""
    import repro
    from repro.data import synthetic

    train, test = get_task()
    nodes = synthetic.make_federated(
        train, node_spec(5, 5, 1), samples_per_node=600, seed=1
    )
    rounds = 120 if full else 60
    for method, mu in (("fedavg", 0.0), ("fedprox", 0.1), ("fedadp", 0.0)):
        cfg = repro.FLConfig(
            num_clients=10,
            clients_per_round=10,
            local_steps=12,
            method=method,
            prox_mu=mu,
            base_lr=0.05,
        )
        server = repro.FedServer("mlr", cfg, nodes, test, batch_size=50, seed=0)
        import time as _t

        t0 = _t.time()
        hist = server.run(rounds, target_acc=0.85, eval_every=2)
        spr = (_t.time() - t0) / max(len(hist.loss), 1)
        emit(
            f"ablation/{method}/rounds_to_85",
            spr * 1e6,
            hist.rounds_to_target or f">{rounds}",
        )


def kernel_micro(full: bool = False) -> None:
    """Pallas kernels (interpret mode) vs XLA reference on identical inputs.

    Interpret-mode timing is NOT TPU performance — the roofline analysis in
    EXPERIMENTS.md covers the TPU projection; this records correctness-path
    cost and the ref/XLA baseline."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import grad_dot, ref, round_stats, weighted_agg

    n = 1 << 22 if full else 1 << 20
    a = jax.random.normal(jax.random.key(0), (n,), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (n,), jnp.float32)
    x = jax.random.normal(jax.random.key(2), (8, n // 8), jnp.float32)
    w = jax.random.uniform(jax.random.key(3), (8,))

    def timeit(fn, *args):
        fn(*args)  # compile
        t0 = time.time()
        for _ in range(3):
            jax.block_until_ready(fn(*args))
        return (time.time() - t0) / 3 * 1e6

    emit(
        "kernel/grad_dot/pallas_interp",
        timeit(grad_dot.grad_dot_stats, a, b),
        f"n={n}",
    )
    emit(
        "kernel/grad_dot/xla_ref",
        timeit(jax.jit(ref.grad_dot_stats), a, b),
        f"n={n}",
    )
    emit(
        "kernel/weighted_agg/pallas_interp",
        timeit(weighted_agg.weighted_agg, w, x),
        f"shape={x.shape}",
    )
    emit(
        "kernel/weighted_agg/xla_ref",
        timeit(jax.jit(ref.weighted_agg), w, x),
        f"shape={x.shape}",
    )
    g = jax.random.normal(jax.random.key(4), (n // 8,), jnp.float32)
    emit(
        "kernel/round_stats/pallas_interp",
        timeit(round_stats.round_stats, x, g),
        f"shape={x.shape}",
    )
    emit(
        "kernel/round_stats/xla_ref",
        timeit(jax.jit(ref.round_stats), x, g),
        f"shape={x.shape}",
    )


def engine_ab(full: bool = False, tiny: bool = False) -> None:
    """Tree vs flat round-engine A/B across a K sweep, plus the
    client-sharded flat engine when more than one device is visible and a
    2D (client x model) mesh sweep when at least 4 are.

    Sweeps K in {8, 32, 64, 128} (chunked kernels: K > 32 used to be a
    trace-time error), times each engine per round, and writes the sweep
    to BENCH_engine.json for the CI bench-smoke artifact: per-record
    measured µs next to the model-bytes HBM-bound floor
    (benchmarks.roofline.flat_round_hbm_bound_us), per-K flat/tree
    ratios, and the K=8 small-d acceptance flag (flat <= 1.2x tree at
    K=8, d=1024 — the cliff the min-elems XLA fallback removes). `tiny`
    shrinks shapes for the interpret-mode CI smoke job.

    On CPU the flat path runs the Pallas kernels in interpret mode, so
    every measured number here is the CORRECTNESS path (labelled "mode":
    "interpret-correctness-path" in the records), not a TPU projection —
    the hbm_bound_us column is the projection."""
    import json

    import jax
    import jax.numpy as jnp

    import repro
    from benchmarks.roofline import flat_round_hbm_bound_us

    ks = (4, 8) if tiny else (8, 32, 64, 128)
    d = 1 << 10 if tiny else (1 << 16 if full else 1 << 14)
    tau, B = 2, 4
    engines = ["tree", "flat"]
    mesh = None
    if jax.device_count() > 1:
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
        engines.append("flat_sharded")
    mode = (
        "interpret-correctness-path"
        if jax.default_backend() == "cpu"
        else jax.default_backend()
    )
    rng = np.random.default_rng(0)
    params = {"w": jnp.zeros((d, 1), jnp.float32), "b": jnp.zeros((1,), jnp.float32)}
    n_flat = d + 1

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    def time_round(cfg, m, params, loss, args):
        rf = jax.jit(repro.make_round_fn(loss, cfg, mesh=m))
        full_args = (repro.init_round_state(cfg, params),) + args
        jax.block_until_ready(rf(*full_args))  # compile
        t0 = time.time()
        reps = 5
        for _ in range(reps):
            jax.block_until_ready(rf(*full_args))
        return (time.time() - t0) / reps * 1e6

    records = []
    ratios = {}
    for K in ks:
        X = jnp.asarray(rng.normal(size=(K, tau, B, d)).astype(np.float32))
        Y = jnp.asarray(rng.normal(size=(K, tau, B, 1)).astype(np.float32))
        sel = jnp.arange(K, dtype=jnp.int32)
        sizes = jnp.ones((K,), jnp.float32)
        us = {}
        for engine in engines:
            if engine == "flat_sharded" and K % jax.device_count():
                continue
            cfg = repro.FLConfig(
                num_clients=K,
                clients_per_round=K,
                local_steps=tau,
                method="fedadp",
                engine=engine,
                base_lr=0.05,
            )
            devs = jax.device_count() if engine == "flat_sharded" else 1
            us[engine] = time_round(cfg, mesh, params, loss_fn, ((X, Y), sel, sizes))
            emit(f"engine_ab/K={K}/{engine}/round", us[engine], f"d={d}")
            records.append(
                {
                    "K": K,
                    "d": d,
                    "engine": engine,
                    "mode": mode,
                    "us_per_round": us[engine],
                    "hbm_bound_us": flat_round_hbm_bound_us(K, n_flat, devices=devs),
                }
            )
        ratios[str(K)] = us["flat"] / us["tree"]
        emit(f"engine_ab/K={K}/flat_over_tree", 0.0, f"{ratios[str(K)]:.3f}")

    # ---- 2D (client x model) mesh sweep: flat vs tree on the same mesh --
    mesh2d_records = []
    dc = jax.device_count()
    if dc >= 4 and dc % 2 == 0:
        d_in, h = max(d // 8, 8), 8
        params2 = {
            "wq": jnp.zeros((d_in, h), jnp.float32),
            "w_down": jnp.zeros((h, 1), jnp.float32),
            "b": jnp.zeros((1,), jnp.float32),
        }
        n2 = d_in * h + h + 1

        def loss2(p, batch):
            x, y = batch
            return jnp.mean(((x @ p["wq"]) @ p["w_down"] + p["b"] - y) ** 2)

        K2 = 8
        X2 = jnp.asarray(rng.normal(size=(K2, tau, B, d_in)).astype(np.float32))
        Y2 = jnp.asarray(rng.normal(size=(K2, tau, B, 1)).astype(np.float32))
        args2 = (
            (X2, Y2),
            jnp.arange(K2, dtype=jnp.int32),
            jnp.ones((K2,), jnp.float32),
        )
        for cdim in sorted({2, dc // 2}):
            mdim = dc // cdim
            m2 = jax.make_mesh((cdim, mdim), ("data", "model"))
            hbm2 = flat_round_hbm_bound_us(K2, n2, devices=dc)
            with m2:
                for engine in ("tree", "flat_sharded"):
                    cfg = repro.FLConfig(
                        num_clients=K2,
                        clients_per_round=K2,
                        local_steps=tau,
                        method="fedadp",
                        engine=engine,
                        base_lr=0.05,
                    )
                    u = time_round(cfg, m2, params2, loss2, args2)
                    emit(f"engine_ab/mesh2d={cdim}x{mdim}/{engine}/round", u, f"n={n2}")
                    mesh2d_records.append(
                        {
                            "mesh": f"{cdim}x{mdim}",
                            "K": K2,
                            "n": n2,
                            "engine": engine,
                            "mode": mode,
                            "us_per_round": u,
                            "hbm_bound_us": hbm2,
                        }
                    )
    from repro.telemetry.manifest import run_manifest

    # acceptance: the K=8 small-d flat-engine cliff stays gone — flat is
    # within 1.2x of tree at K=8, d=1024 on the interpret path.
    k8_cliff_ok = None
    if d == (1 << 10) and "8" in ratios:
        k8_cliff_ok = bool(ratios["8"] <= 1.2)
    payload = {
        "bench": "engine_ab",
        "d": d,
        "tiny": tiny,
        "device_count": jax.device_count(),
        "mode": mode,
        "manifest": run_manifest(),
        "records": records,
        "flat_over_tree": ratios,
        "k8_cliff_ok": k8_cliff_ok,
        "mesh2d": mesh2d_records,
    }
    with open("BENCH_engine.json", "w") as f:
        json.dump(payload, f, indent=2)
    emit("engine_ab/json", 0.0, "BENCH_engine.json")


def transport_sweep(full: bool = False, tiny: bool = False) -> None:
    """Bidirectional wire A/B: (uplink, downlink) x K over the flat engine.

    For each uplink wire format (f32 / bf16 / int8 / int4) and K in
    {8, 32, 64, 128}, times a full federated round through
    `FLConfig(transport=...)` with the reference f32 downlink and reports
    BOTH directions of the wire (`transport.round_bytes`: bytes_up is the
    delta uplink incl. scale side data, bytes_down the model broadcast);
    a second sweep holds the uplink at int4 and walks the downlink
    formats (f32 / bf16 / int8) at the first K. A delta-downlink leg
    then runs a rotating-cohort SUBSET-selection round (per-client
    broadcast state, downlink_delta=True) and reports the ACTUAL
    delta-vs-full down-byte split from the tel/bytes_down_* metrics —
    the number the static broadcast figure over-states whenever clients
    resync. Everything lands in BENCH_transport.json for the CI
    bench-smoke artifact.

    Unless `tiny`, also pins convergence parity on the non-IID synthetic
    task (5 IID + 5 one-class nodes): rounds-to-target under the int8 and
    int4 uplinks AND under the fully-compressed int4+int8-downlink pair
    must stay within 10% of the f32 wire (the acceptance bound; the same
    matrix is pinned as a TEST in tests/test_golden_convergence.py).

    On CPU the kernels run in interpret mode, so us_per_round measures the
    correctness path; bytes are exact either way."""
    import json

    import jax
    import jax.numpy as jnp

    from repro import transport as transport_mod
    import repro

    ks = (4, 8) if tiny else (8, 32, 64, 128)
    d = 1 << 10 if tiny else (1 << 16 if full else 1 << 14)
    tau, B = 2, 4
    n_params = d + 1  # w (d, 1) + b (1,)
    rng = np.random.default_rng(0)
    params = {"w": jnp.zeros((d, 1), jnp.float32), "b": jnp.zeros((1,), jnp.float32)}

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    def time_round(K, data, tr, dl):
        cfg = repro.FLConfig(
            num_clients=K,
            clients_per_round=K,
            local_steps=tau,
            method="fedadp",
            engine="flat",
            transport=tr,
            downlink=dl,
            base_lr=0.05,
        )
        rf = jax.jit(repro.make_round_fn(loss_fn, cfg))
        sel = jnp.arange(K, dtype=jnp.int32)
        sizes = jnp.ones((K,), jnp.float32)
        args = (repro.init_round_state(cfg, params), data, sel, sizes)
        jax.block_until_ready(rf(*args))  # compile
        t0 = time.time()
        reps = 5
        for _ in range(reps):
            jax.block_until_ready(rf(*args))
        return (time.time() - t0) / reps * 1e6

    records = []

    def record(K, data, tr, dl):
        us = time_round(K, data, tr, dl)
        rb = transport_mod.round_bytes(K, n_params, tr, dl)
        emit(
            f"transport/K={K}/{tr}/dl={dl}/round",
            us,
            f"up={rb['up']} down={rb['down']}",
        )
        records.append(
            {
                "K": K,
                "d": d,
                "transport": tr,
                "downlink": dl,
                "us_per_round": us,
                "bytes_up": rb["up"],
                "bytes_down": rb["down"],
                "bytes_per_round": rb["total"],
            }
        )
        return rb

    for K in ks:
        data = (
            jnp.asarray(rng.normal(size=(K, tau, B, d)).astype(np.float32)),
            jnp.asarray(rng.normal(size=(K, tau, B, 1)).astype(np.float32)),
        )
        wb = {tr: record(K, data, tr, "f32")["up"] for tr in transport_mod.TRANSPORTS}
        emit(
            f"transport/K={K}/int8_bytes_over_f32",
            0.0,
            f"{wb['int8'] / wb['f32']:.4f}",
        )
        # acceptance: the int4 uplink moves ~0.125x the f32 bytes
        emit(
            f"transport/K={K}/int4_bytes_over_f32",
            0.0,
            f"{wb['int4'] / wb['f32']:.4f}",
        )
        if K == ks[0]:
            # downlink sweep at the smallest K: uplink held at int4, the
            # broadcast walked over every downlink format
            down = {
                dl: record(K, data, "int4", dl)["down"]
                for dl in transport_mod.DOWNLINKS
                if dl != "f32"
            }
            down["f32"] = transport_mod.round_bytes(K, n_params, "int4")["down"]
            emit(
                f"transport/K={K}/int8_down_over_f32_down",
                0.0,
                f"{down['int8'] / down['f32']:.4f}",
            )

    # delta-downlink byte split: a short SUBSET-selection run (half the
    # population per round) over the per-client broadcast state, with the
    # actual per-round delta-vs-full down bytes read back from the
    # tel/bytes_down_* metrics (resyncs pay a full quantized model; the
    # static round_bytes broadcast figure is only the degenerate
    # full-participation bound).
    K = ks[0]
    ksel = K // 2
    cfg = repro.FLConfig(
        num_clients=K,
        clients_per_round=ksel,
        local_steps=tau,
        method="fedadp",
        engine="flat",
        transport="int4",
        downlink="int8",
        downlink_delta=True,
        downlink_ring=2,
        base_lr=0.05,
        telemetry="node",
    )
    rf = jax.jit(repro.make_round_fn(loss_fn, cfg))
    data = (
        jnp.asarray(rng.normal(size=(ksel, tau, B, d)).astype(np.float32)),
        jnp.asarray(rng.normal(size=(ksel, tau, B, 1)).astype(np.float32)),
    )
    sizes = jnp.ones((ksel,), jnp.float32)
    state = repro.init_round_state(cfg, params)
    delta_rounds, down_delta = [], 0.0
    down_full = 0.0
    T = 8
    for t in range(T):
        # rotate the cohort so clients fall behind and re-pull: the first
        # pass pays full-model resyncs (never-pulled clients), later
        # rounds pay multi-version delta catch-ups through the ring
        sel = jnp.asarray([(t * ksel + i) % K for i in range(ksel)], jnp.int32)
        state, metrics = rf(state, data, sel, sizes)
        dd = float(metrics["tel/bytes_down_delta"])
        df = float(metrics["tel/bytes_down_full"])
        down_delta += dd
        down_full += df
        delta_rounds.append(
            {"round": t, "bytes_down_delta": dd, "bytes_down_full": df}
        )
    static_down = transport_mod.round_bytes(ksel, n_params, "int4", "int8")["down"]
    emit(
        f"transport/delta_split/K={K}/sel={ksel}",
        0.0,
        f"delta={down_delta:.0f} full={down_full:.0f} "
        f"static_down={static_down * T}",
    )
    delta_split = {
        "K": K,
        "clients_per_round": ksel,
        "downlink_ring": 2,
        "transport": "int4",
        "downlink": "int8",
        "rounds": delta_rounds,
        "bytes_down_delta_total": down_delta,
        "bytes_down_full_total": down_full,
        "static_broadcast_down_total": static_down * T,
    }

    convergence = None
    if not tiny:
        rounds = 120 if full else 60
        per = {}
        for tr, dl in (
            ("f32", "f32"),
            ("int8", "f32"),
            ("int4", "f32"),
            ("int4", "int8"),
        ):
            hist, spr = run_fl(
                "fedadp",
                node_spec(5, 5, 1),
                rounds=rounds,
                target=0.85,
                engine="flat",
                transport=tr,
                downlink=dl,
            )
            name = tr if dl == "f32" else f"{tr}+dl_{dl}"
            per[name] = hist.rounds_to_target
            emit(
                f"transport/convergence/{name}/rounds_to_85",
                spr * 1e6,
                per[name] or f">{rounds}",
            )
        # a wire that never reached the target is a parity FAILURE, not a
        # skipped measurement — record it as such so the artifact can't be
        # mistaken for a --tiny run (where convergence stays null).
        ratios = {}
        for name in ("int8", "int4", "int4+dl_int8"):
            r = per[name] / per["f32"] if per["f32"] and per[name] else None
            ratios[name] = r
            emit(
                f"transport/convergence/{name}_over_f32",
                0.0,
                f"{r:.3f}" if r else "no-convergence",
            )
        convergence = {
            "rounds": per,
            "ratios": ratios,
            "within_10pct": all(r is not None and r <= 1.1 for r in ratios.values()),
        }

    from repro.telemetry.manifest import run_manifest

    payload = {
        "bench": "transport_sweep",
        "d": d,
        "n_params": n_params,
        "tiny": tiny,
        "transports": list(transport_mod.TRANSPORTS),
        "downlinks": list(transport_mod.DOWNLINKS),
        "manifest": run_manifest(),
        "records": records,
        "downlink_delta": delta_split,
        "convergence": convergence,
    }
    with open("BENCH_transport.json", "w") as f:
        json.dump(payload, f, indent=2)
    emit("transport/json", 0.0, "BENCH_transport.json")


def driver_ab(full: bool = False, tiny: bool = False) -> None:
    """Python-loop vs scanned round driver A/B across a K sweep.

    Both paths run the SAME compiled device-resident step (selection +
    batching + round + conditional eval from the device RNG); the
    python-loop path dispatches it once per round and `device_get`s the
    metrics each time (the pre-driver FedServer cadence), while the
    scanned path folds all R rounds into one `lax.scan` dispatch
    (`FedServer.run(mode="scanned")` with block=R). The gap is therefore pure
    dispatch/sync overhead — exactly what the device-resident driver
    exists to remove. Results land in BENCH_driver.json for the CI
    bench-smoke artifact; acceptance is scanned <= python-loop at every K.
    """
    import json

    import repro
    from repro.data import synthetic

    ks = (4, 8) if tiny else (8, 32, 64, 128)
    samples, batch = (8, 4) if tiny else (100, 50)
    reps, R = (3, 8) if tiny else (5, 8)
    train, test = synthetic.make_image_task(
        seed=0, num_train=512 if tiny else 4000, num_test=128 if tiny else 512
    )
    records, ratios = [], {}
    for K in ks:
        nodes = synthetic.make_federated(
            train, [("iid", None)] * K, samples_per_node=samples, seed=1
        )
        cfg = repro.FLConfig(
            num_clients=K,
            clients_per_round=K,
            local_steps=samples // batch,
            method="fedadp",
            base_lr=0.05,
        )
        server = repro.FedServer("mlr", cfg, nodes, test, batch_size=batch, seed=0)

        def loop_path():
            for _ in range(R):
                server.step()

        def scan_path():
            server.run(R, eval_every=0, mode="scanned", block=R)

        server.step()  # compile the stepwise dispatch
        scan_path()  # compile the scan block
        # interleave the two paths' reps so slow machine-load drift hits
        # both equally (back-to-back rep blocks skew the ratio)
        loop_us, scan_us = _best_us_interleaved(loop_path, scan_path, reps)
        loop_us, scan_us = loop_us / R, scan_us / R

        emit(f"driver_ab/K={K}/python_loop/round", loop_us, f"R={R}")
        emit(f"driver_ab/K={K}/scanned/round", scan_us, f"R={R}")
        ratios[K] = scan_us / loop_us
        emit(f"driver_ab/K={K}/scanned_over_loop", 0.0, f"{ratios[K]:.3f}")
        records += [
            {"K": K, "path": "python_loop", "us_per_round": loop_us},
            {"K": K, "path": "scanned", "us_per_round": scan_us},
        ]
    from repro.telemetry.manifest import run_manifest

    payload = {
        "bench": "driver_ab",
        "tiny": tiny,
        "rounds_per_dispatch": R,
        "manifest": run_manifest(),
        "records": records,
        "scanned_over_loop": {str(k): v for k, v in ratios.items()},
        # the acceptance claim the artifact carries: the scanned driver is
        # never slower than the per-round dispatch loop
        "scanned_leq_loop_all_k": all(v <= 1.0 for v in ratios.values()),
    }
    with open("BENCH_driver.json", "w") as f:
        json.dump(payload, f, indent=2)
    emit("driver_ab/json", 0.0, "BENCH_driver.json")


def telemetry_bench(full: bool = False, tiny: bool = False) -> None:
    """Telemetry-layer end-to-end: a scanned fedadp run streamed to a
    JSONL sink, then summarized back by the flstat logic.

    Writes the stream itself as the artifact (BENCH_telemetry.jsonl —
    the CI bench-smoke job schema-validates it and asserts the softmax
    weight-sum invariant with scripts/flstat.py) and emits the
    acceptance claim: rounds-to-85% recomputed from the stream ALONE
    must agree with the in-process History. `tiny` shrinks the task for
    the CI smoke job (the target is usually not reached there — the
    claim then checks that both sides agree on "not reached")."""
    from repro.telemetry import report as tel_report
    from repro.telemetry.sinks import JSONLSink, load_events

    target = 0.85
    rounds = 10 if tiny else (120 if full else 60)
    spec = node_spec(2, 2, 1) if tiny else node_spec(5, 5, 1)
    sink = JSONLSink("BENCH_telemetry.jsonl")
    hist, spr = run_fl(
        "fedadp", spec, rounds=rounds, target=target, scan=True,
        samples=100 if tiny else 600, batch_size=25 if tiny else 50,
        telemetry="node", sink=sink,
    )
    sink.close()
    events = load_events(sink.path)
    s = tel_report.summarize(events, target=target)
    checked = tel_report.check_weight_sums(events)
    emit("telemetry/rounds_streamed", spr * 1e6, s["rounds"])
    emit("telemetry/weight_sum_rounds_ok", 0.0, checked)
    emit(
        "telemetry/rounds_to_85/flstat",
        0.0,
        s["rounds_to_target"] or f">{rounds}",
    )
    emit(
        "telemetry/rounds_to_85/agrees_with_history",
        0.0,
        s["rounds_to_target"] == hist.rounds_to_target,
    )
    emit("telemetry/jsonl", 0.0, "BENCH_telemetry.jsonl")


def _best_us_interleaved(fn_a, fn_b, reps: int):
    """Best-of-`reps` wall time of each fn in microseconds, reps
    interleaved a/b/a/b so load drift cannot bias the comparison."""
    best_a = best_b = float("inf")
    for _ in range(reps):
        t0 = time.time()
        fn_a()
        best_a = min(best_a, time.time() - t0)
        t0 = time.time()
        fn_b()
        best_b = min(best_b, time.time() - t0)
    return best_a * 1e6, best_b * 1e6


def roofline_table(full: bool = False) -> None:
    """Post-process results/dryrun.jsonl into roofline terms (if present)."""
    import json
    import os

    # prefer the loop-aware records (scoped analysis + perf-iteration tags)
    candidates = ("results/roofline.jsonl", "results/dryrun.jsonl")
    path = next((p for p in candidates if os.path.exists(p)), None)
    if path is None:
        emit("roofline/skipped", 0.0, "run repro.launch.dryrun --all first")
        return
    from benchmarks.roofline import load_records, roofline_rows

    rows = roofline_rows(load_records(path))
    for r in rows:
        emit(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            0.0,
            f"comp={r['t_compute']:.2e}s mem={r['t_memory']:.2e}s "
            f"coll={r['t_collective']:.2e}s dom={r['bottleneck']}",
        )


BENCHES = {
    "table1": table1_rounds,
    "fig1": fig1_noniid_impact,
    "fig5": fig5_general_heterogeneity,
    "fig6": fig6_alpha_sweep,
    "fig7": fig7_divergence,
    "ablation": method_ablation,
    "kernels": kernel_micro,
    "engine": engine_ab,
    "transport": transport_sweep,
    "driver": driver_ab,
    "telemetry": telemetry_bench,
    "roofline": roofline_table,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale settings (slow)")
    ap.add_argument("--tiny", action="store_true", help="CI smoke shapes")
    ap.add_argument("--only", default=None, choices=sorted(BENCHES))
    args = ap.parse_args()
    names = [args.only] if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for name in names:
        kwargs = {"full": args.full}
        if name in ("engine", "transport", "driver", "telemetry"):
            kwargs["tiny"] = args.tiny
        BENCHES[name](**kwargs)


if __name__ == "__main__":
    main()
