"""Shared benchmark harness utilities."""
from __future__ import annotations

import time

import repro
from repro.data import synthetic

_TASK_CACHE: dict = {}


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def get_task(num_train: int = 12000, num_test: int = 2000, seed: int = 0):
    key = (num_train, num_test, seed)
    if key not in _TASK_CACHE:
        _TASK_CACHE[key] = synthetic.make_image_task(
            seed=seed, num_train=num_train, num_test=num_test
        )
    return _TASK_CACHE[key]


def node_spec(n_iid: int, n_noniid: int, x: int):
    return [("iid", None)] * n_iid + [("xclass", x)] * n_noniid


def run_fl(
    method: str,
    spec: list,
    *,
    model: str = "mlr",
    rounds: int = 60,
    target: float | None = 0.85,
    alpha: float = 5.0,
    batch_size: int = 50,
    base_lr: float = 0.05,
    samples: int = 600,
    seed: int = 0,
    eval_every: int = 2,
    engine: str = "tree",
    transport: str = "f32",
    downlink: str = "f32",
    downlink_delta: bool = False,
    downlink_ring: int = 8,
    clients_per_round: int | None = None,
    group_size: int = 512,
    mesh=None,
    scan: bool = False,
    scan_block: int = 8,
    aggregation: str = "sync",
    buffer_m: int = 0,
    staleness_beta: float = 0.3,
    straggle_prob: float = 0.0,
    straggle_max: int = 1,
    dropout_prob: float = 0.0,
    arrival_fn=None,
    telemetry: str | None = None,
    sink=None,
    telemetry_every: int = 1,
):
    """Returns (history, seconds_per_round).

    `scan=True` drives the run through the scanned device-resident driver
    (`run(mode="scanned")`, `scan_block` rounds per dispatch) instead of
    the stepwise per-round loop; both share the same compiled step, so
    the trajectory is identical and only the dispatch granularity (and
    wall clock) differs. `aggregation="buffered"` plus the
    buffer_m/staleness/straggle/dropout knobs (or an explicit
    `arrival_fn` schedule) run the buffered-async server instead of the
    lockstep round — rounds then count server ticks.

    `telemetry="node"` builds the config with per-node tel/* metrics and
    `sink` streams the TIMED run (warmup rounds never reach the sink) as
    repro.telemetry schema events, `telemetry_every` subsampling rounds.

    `clients_per_round` defaults to full participation (every node of
    `spec` selected every round); pass a smaller K for subset selection
    — the regime where the per-client delta-downlink state matters.
    """
    train, test = get_task()
    nodes = synthetic.make_federated(train, spec, samples_per_node=samples,
                                     seed=seed + 1)
    n = len(spec)
    cfg = repro.FLConfig(
        num_clients=n,
        clients_per_round=n if clients_per_round is None else clients_per_round,
        local_steps=samples // batch_size,
        method=method, alpha=alpha, base_lr=base_lr,
        engine=engine, transport=transport, downlink=downlink,
        downlink_delta=downlink_delta, downlink_ring=downlink_ring,
        group_size=group_size,
        aggregation=aggregation, buffer_m=buffer_m,
        staleness_beta=staleness_beta, straggle_prob=straggle_prob,
        straggle_max=straggle_max, dropout_prob=dropout_prob,
        telemetry=telemetry,
    )
    server = repro.FedServer(model, cfg, nodes, test, batch_size=batch_size,
                             seed=seed, mesh=mesh, arrival_fn=arrival_fn)
    # warm the jit cache on the chosen dispatch path with throwaway
    # rounds, then reset so the timed trajectory still starts at round 0
    if scan:
        server.run(min(rounds, scan_block), eval_every=eval_every,
                   mode="scanned", block=scan_block)
    else:
        server.step(eval_every=eval_every)
    server.reset()
    t0 = time.time()
    hist = server.run(rounds, target_acc=target, eval_every=eval_every,
                      mode="scanned" if scan else "stepwise",
                      block=scan_block, sink=sink,
                      telemetry_every=telemetry_every)
    dt = time.time() - t0
    done = len(hist.loss) or 1
    return hist, dt / done
