"""Roofline-term derivation from dry-run records (EXPERIMENTS.md §Roofline).

Terms (per device; the compiled module IS the per-device program, so
cost_analysis/HLO figures are already per-chip — dividing a global total by
`chips` is the same number):

  t_compute    = HLO_FLOPs_per_dev / 197e12        (bf16 MXU peak, v5e)
  t_memory     = HLO_bytes_per_dev / 819e9         (HBM bandwidth)
  t_collective = collective_result_bytes / 50e9    (per-link ICI)

plus MODEL_FLOPS = 6*N*D (6*N_active*D for MoE) and the usefulness ratio
MODEL_FLOPS / (HLO_FLOPs * chips).
"""
from __future__ import annotations

import json
from typing import Iterable

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


_WIRE_BYTES = {"f32": 4.0, "bf16": 2.0, "int8": 1.0, "int4": 0.5}


def flat_round_hbm_bound_us(K: int, n: int, transport: str = "f32",
                            devices: int = 1) -> float:
    """Model-bytes HBM floor (µs) for one flat-engine aggregation round.

    The fused engine streams the (K, N) wire buffer three times — the
    psi-aggregate, the stats pass, and the weighted aggregate — so the
    floor is 3 * K * N * wire_bytes / HBM_BW per device (the buffer is
    evenly tiled over `devices`; the O(N) g/delta vectors and O(K) stat
    vectors are noise against K passes over the buffer). This is the
    TPU-projection column printed next to measured µs by
    `benchmarks/run.py --only engine`; on CPU the measured number is the
    interpret-mode correctness path and sits orders of magnitude above
    this floor by design.
    """
    bpe = _WIRE_BYTES[transport]
    return 3.0 * K * n * bpe / devices / HBM_BW * 1e6


def load_records(path: str) -> list[dict]:
    recs = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"], r.get("tag", "baseline"))] = r
    return list(recs.values())


def _tokens(rec: dict) -> int:
    meta = rec.get("meta", {})
    if "K" in meta:  # train: K clients x tau steps x B x T
        seq = {"train_4k": 4096}.get(rec["shape"], 4096)
        return meta["K"] * meta["tau"] * meta["B"] * seq
    if "T" in meta:  # prefill
        return meta["B"] * meta["T"]
    return meta.get("B", 1)  # decode: one token per sequence


def model_flops(rec: dict, n_active: int) -> float:
    mult = 6.0 if rec["shape"] == "train_4k" else 2.0  # fwd+bwd vs fwd
    return mult * n_active * _tokens(rec)


def roofline_rows(records: Iterable[dict]) -> list[dict]:
    from repro.configs.registry import ARCHS

    rows = []
    for r in sorted(records, key=lambda x: (x["arch"], x["shape"], x["mesh"],
                                            x.get("tag", "baseline"))):
        chips = r["devices"]
        # prefer the loop-aware scoped analysis (XLA cost_analysis counts
        # while-loop bodies once; see repro.launch.hlo_scoped)
        s = r.get("scoped")
        if s and s.get("flops", 0) > 0:
            flops, nbytes = s["flops"], s["hbm_bytes"]
            coll = s["collectives"].get("total", 0)
        else:
            flops, nbytes = r["flops"], r["bytes_accessed"]
            coll = r["collectives"].get("total", 0)
        t_comp = flops / PEAK_FLOPS_BF16 if flops > 0 else 0.0
        t_mem = nbytes / HBM_BW if nbytes > 0 else 0.0
        t_coll = coll / ICI_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        bottleneck = max(terms, key=terms.get)
        cfg = ARCHS.get(r["arch"])
        mf = model_flops(r, cfg.active_param_count()) if cfg else 0.0
        useful = mf / (flops * chips) if flops > 0 else 0.0
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "tag": r.get("tag", "baseline"),
            "t_compute": t_comp, "t_memory": t_mem, "t_collective": t_coll,
            "bottleneck": bottleneck, "model_flops": mf,
            "useful_ratio": useful,
            "temp_gib": r["memory"]["temp_bytes"] / 2**30,
            "compile_s": r.get("compile_s", 0),
        })
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | tag | t_comp (s) | t_mem (s) | t_coll (s) "
           "| bottleneck | useful | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    body = "".join(
        f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['tag']} "
        f"| {r['t_compute']:.2e} | {r['t_memory']:.2e} | {r['t_collective']:.2e} "
        f"| **{r['bottleneck']}** | {r['useful_ratio']:.2f} "
        f"| {r['temp_gib']:.1f} |\n"
        for r in rows
    )
    return hdr + body


if __name__ == "__main__":
    import sys

    rows = roofline_rows(load_records(sys.argv[1] if len(sys.argv) > 1
                                      else "results/dryrun.jsonl"))
    print(markdown_table(rows))
