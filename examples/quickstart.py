"""Quickstart: FedAdp vs FedAvg on a non-IID federated image task.

    PYTHONPATH=src python examples/quickstart.py

Ten nodes (5 IID + 5 one-class non-IID), multinomial logistic regression,
~1 minute on CPU. Reproduces the paper's headline qualitatively: FedAdp
reaches the accuracy target in far fewer communication rounds.
"""
import sys

sys.path.insert(0, "src")

import repro
from repro.data import synthetic


def main() -> None:
    print("building synthetic 10-class image task (offline MNIST stand-in)...")
    train, test = synthetic.make_image_task(seed=0, num_train=12000, num_test=2000)
    nodes = synthetic.make_federated(
        train, [("iid", None)] * 5 + [("xclass", 1)] * 5,
        samples_per_node=600, seed=1,
    )
    target = 0.85
    results = {}
    for method in ("fedavg", "fedadp"):
        cfg = repro.FLConfig(num_clients=10, clients_per_round=10,
                          local_steps=12, method=method, base_lr=0.05)
        server = repro.FedServer("mlr", cfg, nodes, test, batch_size=50, seed=0)
        hist = server.run(rounds=60, target_acc=target, eval_every=2)
        r = hist.rounds_to_target
        results[method] = r
        print(f"{method:8s}: rounds to {target:.0%} accuracy = "
              f"{r if r else '>60'} (final acc {hist.final_accuracy:.3f})")
    if results["fedadp"] and results["fedavg"]:
        red = 100 * (1 - results["fedadp"] / results["fedavg"])
        print(f"\nFedAdp communication-round reduction: {red:.1f}% "
              f"(paper reports up to 54.1% on MNIST)")


if __name__ == "__main__":
    main()
