"""End-to-end federated LANGUAGE-MODEL training with FedAdp.

    PYTHONPATH=src python examples/fl_lm_train.py --preset small --rounds 50
    PYTHONPATH=src python examples/fl_lm_train.py --preset 100m --rounds 200

Clients hold non-IID token streams (client-permuted Zipf vocabularies);
each round runs tau local SGD steps per client and a FedAdp-weighted
aggregation — the same compiled round the multi-pod dry-run lowers, here on
the host device. Checkpoints land in results/.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.checkpoint import io as ckpt
from repro.configs import registry
from repro.data import synthetic
from repro.models import transformer
from repro.models.config import ModelConfig

PRESETS = {
    # ~20M params: fast CPU demo
    "small": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                  d_ff=1024, vocab_size=8192),
    # ~110M params: the "train a ~100M model" end-to-end driver
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 d_ff=3072, vocab_size=32768),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="small")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--method", choices=["fedadp", "fedavg"], default="fedadp")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--out", default="results/fl_lm.npz")
    args = ap.parse_args()

    cfg = ModelConfig(name=f"fl-lm-{args.preset}", arch_type="dense",
                      tie_embeddings=True, dtype="float32", **PRESETS[args.preset])
    params = transformer.init_params(jax.random.key(0), cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model {cfg.name}: {n/1e6:.1f}M params; "
          f"K={args.clients} tau={args.tau} B={args.batch} T={args.seq}")

    flcfg = repro.FLConfig(num_clients=args.clients, clients_per_round=args.clients,
                        local_steps=args.tau, method=args.method,
                        base_lr=args.lr, lr_decay=0.999)
    round_fn = jax.jit(repro.make_round_fn(
        lambda p, b: transformer.loss_fn(p, cfg, b), flcfg))
    state = repro.init_round_state(flcfg, params)
    sel = jnp.arange(args.clients, dtype=jnp.int32)
    sizes = jnp.ones((args.clients,))

    for r in range(args.rounds):
        toks = synthetic.lm_token_batches(
            seed=r, num_clients=args.clients, batch=args.tau * args.batch,
            seq=args.seq, vocab=cfg.vocab_size,
        ).reshape(args.clients, args.tau, args.batch, args.seq)
        t0 = time.time()
        state, m = round_fn(state, {"tokens": jnp.asarray(toks)}, sel, sizes)
        if r % 5 == 0 or r == args.rounds - 1:
            w = np.asarray(m["weights"])
            print(f"round {r:4d} loss {float(m['loss']):.4f} "
                  f"div {float(m['divergence']):.3f} "
                  f"w=[{', '.join(f'{x:.3f}' for x in w)}] "
                  f"({time.time()-t0:.1f}s)")
    # full RoundState snapshot: repro.state_from_tree(flcfg, ckpt.load(path))
    # rebuilds the exact carry (params, angles, EF, RNG, round) to resume
    ckpt.save(args.out, repro.state_to_tree(state))
    print("checkpoint ->", args.out)


if __name__ == "__main__":
    main()
