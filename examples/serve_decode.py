"""Serving demo: prefill + batched greedy decode for any assigned arch
(reduced same-family variant on CPU; the full configs are exercised by the
multi-pod dry-run).

    PYTHONPATH=src python examples/serve_decode.py --arch deepseek-v2-lite-16b
    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-3b --steps 32
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import transformer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(registry.ARCHS), default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args()

    cfg = registry.smoke(args.arch)
    params = transformer.init_params(jax.random.key(0), cfg)
    B, T = args.batch, args.prompt_len
    max_len = T + args.steps
    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, T), 0,
                                          cfg.vocab_size)}
    if cfg.vision_prefix:
        batch["vision_embeds"] = jnp.zeros((B, cfg.vision_prefix, cfg.d_model),
                                           cfg.jdtype)
    if cfg.encoder_layers:
        batch["enc_embeds"] = jnp.zeros((B, cfg.encoder_len, cfg.d_model),
                                        cfg.jdtype)

    prefill = jax.jit(lambda p, b: transformer.forward(p, cfg, b,
                                                       mode="prefill",
                                                       max_len=max_len))
    decode = jax.jit(lambda p, t, c, pos: transformer.decode_step(p, cfg, t, c,
                                                                  pos, {}))
    t0 = time.time()
    logits, _, cache = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    print(f"[{cfg.name}] prefill B={B} T={T}: {time.time()-t0:.2f}s")

    out = [tok]
    t0 = time.time()
    for i in range(args.steps - 1):
        logits, cache = decode(params, tok, cache, jnp.int32(T + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = (time.time() - t0) / (args.steps - 1)
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.steps} tokens/seq, {dt*1e3:.1f} ms/step/batch")
    print("sample token ids:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
