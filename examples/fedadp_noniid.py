"""Paper reproduction driver: Table I / Figs. 4-7 protocol on the synthetic
image task (offline stand-in for MNIST/FashionMNIST).

    PYTHONPATH=src python examples/fedadp_noniid.py --model mlr --setting 5iid+5non1
    PYTHONPATH=src python examples/fedadp_noniid.py --model cnn --rounds 300 --full

Writes per-round accuracy/loss/divergence JSON to results/.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, "src")

import numpy as np

import repro
from repro.data import synthetic

SETTINGS = {
    "10iid": [("iid", None)] * 10,
    "3iid+7non1": [("iid", None)] * 3 + [("xclass", 1)] * 7,
    "5iid+5non1": [("iid", None)] * 5 + [("xclass", 1)] * 5,
    "6iid+4non1": [("iid", None)] * 6 + [("xclass", 1)] * 4,
    "3iid+7non2": [("iid", None)] * 3 + [("xclass", 2)] * 7,
    "5iid+5non2": [("iid", None)] * 5 + [("xclass", 2)] * 5,
    "6iid+4non2": [("iid", None)] * 6 + [("xclass", 2)] * 4,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=["mlr", "cnn"], default="mlr")
    ap.add_argument("--setting", choices=sorted(SETTINGS), default="5iid+5non1")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--target", type=float, default=0.9)
    ap.add_argument("--alpha", type=float, default=5.0)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--out", default="results")
    args = ap.parse_args()

    batch = args.batch or (32 if args.model == "cnn" else 50)
    lr = args.lr or (0.05 if args.model == "mlr" else 0.02)
    train, test = synthetic.make_image_task(seed=0, num_train=20000, num_test=3000)
    nodes = synthetic.make_federated(train, SETTINGS[args.setting],
                                     samples_per_node=600, seed=1)
    out = {}
    for method in ("fedavg", "fedadp"):
        cfg = repro.FLConfig(num_clients=10, clients_per_round=10,
                          local_steps=600 // batch, method=method,
                          alpha=args.alpha, base_lr=lr)
        server = repro.FedServer(args.model, cfg, nodes, test, batch_size=batch, seed=0)
        hist = server.run(args.rounds, target_acc=args.target, eval_every=2,
                          verbose=True)
        out[method] = {
            "rounds_to_target": hist.rounds_to_target,
            "accuracy": hist.accuracy,
            "loss": hist.loss,
            "divergence": hist.divergence,
        }
        print(f"[{args.model}/{args.setting}] {method}: rounds-to-"
              f"{args.target:.0%} = {hist.rounds_to_target or 'N/A'}")

    os.makedirs(args.out, exist_ok=True)
    path = f"{args.out}/fedadp_{args.model}_{args.setting}.json"
    with open(path, "w") as f:
        json.dump(out, f)
    print("wrote", path)
    a, b = out["fedadp"]["rounds_to_target"], out["fedavg"]["rounds_to_target"]
    if a and b:
        print(f"round reduction: {100*(1-a/b):.1f}%")


if __name__ == "__main__":
    main()
