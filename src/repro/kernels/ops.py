"""Jitted tree-level wrappers over the Pallas kernels.

These mirror `repro.core.treemath` but stream through the fused kernels —
used by the FL aggregation layer when `use_pallas=True` (TPU) and by the
kernel benchmarks. Trees are flattened leaf-by-leaf and the per-leaf
partial statistics are combined, so no concatenated copy of the parameter
vector is ever materialized.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import grad_dot, weighted_agg

PyTree = Any


def tree_dot_and_norms(a: PyTree, b: PyTree, *, interpret: bool = True):
    dots, nas, nbs = [], [], []
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        d, na, nb = grad_dot.grad_dot_stats(x, y, interpret=interpret)
        dots.append(d)
        nas.append(na)
        nbs.append(nb)
    return (
        jnp.sum(jnp.stack(dots)),
        jnp.sum(jnp.stack(nas)),
        jnp.sum(jnp.stack(nbs)),
    )


def tree_weighted_sum(stacked: PyTree, w: jax.Array, *, interpret: bool = True):
    """sum_k w[k] * tree[k] for leaves with leading K axis."""

    def leaf(x):
        K = x.shape[0]
        y = weighted_agg.weighted_agg(w, x.reshape(K, -1), interpret=interpret)
        return y.reshape(x.shape[1:]).astype(x.dtype)

    return jax.tree.map(leaf, stacked)


def tree_vdot_batched(stacked: PyTree, single: PyTree, *, interpret: bool = True):
    parts = []
    for x, g in zip(jax.tree.leaves(stacked), jax.tree.leaves(single)):
        parts.append(
            weighted_agg.batched_dot(
                x.reshape(x.shape[0], -1), g.reshape(-1), interpret=interpret
            )
        )
    return jnp.sum(jnp.stack(parts), axis=0)
