"""Fused causal attention Pallas kernel (flash-attention, TPU target).

§Perf identified the f32 (B, H, T, T) score tensor as the dominant HBM
term for every dense arch's train/prefill: XLA materializes scores and
probs to HBM. This kernel computes one (blk_q x T) stripe at a time with
an online softmax — scores/probs never leave VMEM.

Tiling: grid = (B*H, T/blk_q). Per step the kernel holds
  q     (blk_q, d)        — 64 KiB at blk_q=128, d=128, f32
  k, v  (T, d) each       — 2 MiB at T=4096 (streamed blk_k-wise in-loop)
  acc/m/l + p (blk_q, blk_k)
comfortably inside the ~16 MiB v5e VMEM for T <= 8k; longer sequences
want a 3-D grid streaming K/V from HBM (left as the documented next step —
the q-chunked jnp path in models/attention.py already covers that regime).

Validated against ref.flash_attention (pure jnp) in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, blk_k: int, scale: float,
            causal: bool):
    blk_q, d = q_ref.shape[1], q_ref.shape[2]
    T = k_ref.shape[1]
    q = q_ref[0].astype(jnp.float32) * scale
    q_pos = pl.program_id(1) * blk_q + jax.lax.broadcasted_iota(
        jnp.int32, (blk_q, blk_k), 0
    )

    def body(i, carry):
        acc, m, l = carry
        k = k_ref[0, pl.ds(i * blk_k, blk_k), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(i * blk_k, blk_k), :].astype(jnp.float32)
        s = q @ k.T  # (blk_q, blk_k)
        if causal:
            k_pos = i * blk_k + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 1
            )
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((blk_q, d), jnp.float32)
    m0 = jnp.full((blk_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((blk_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, T // blk_k, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = True):
    """q/k/v: (BH, T, d) (heads pre-flattened; GQA callers repeat kv).

    Returns (BH, T, d) in q's dtype. T must divide by blk_q and blk_k.

    Differentiable via custom_vjp: the forward is the fused Pallas kernel;
    the backward recomputes scores with the standard jnp formulation (a
    fused flash BACKWARD kernel is the documented next step — the forward
    is where the (T x T) HBM materialization hurts prefill/serving).
    """
    return _flash_fwd_impl(q, k, v, causal, blk_q, blk_k, interpret)


def _flash_fwd_impl(q, k, v, causal, blk_q, blk_k, interpret):
    BH, T, d = q.shape
    assert k.shape == v.shape == (BH, T, d)
    assert T % blk_q == 0 and T % blk_k == 0
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_kernel, blk_k=blk_k, scale=scale, causal=causal)
    return pl.pallas_call(
        kern,
        grid=(BH, T // blk_q),
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, T, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, T, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def _flash_fwd(q, k, v, causal, blk_q, blk_k, interpret):
    return _flash_fwd_impl(q, k, v, causal, blk_q, blk_k, interpret), (q, k, v)


def _flash_bwd(causal, blk_q, blk_k, interpret, res, do):
    q, k, v = res
    T = q.shape[1]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(T)[None, :] <= jnp.arange(T)[:, None]
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    dof = do.astype(jnp.float32)
    dv = jnp.einsum("bts,btd->bsd", p, dof)
    dp = jnp.einsum("btd,bsd->bts", dof, v.astype(jnp.float32))
    ds = p * (dp - jnp.sum(p * dp, axis=-1, keepdims=True)) * scale
    dq = jnp.einsum("bts,bsd->btd", ds, k.astype(jnp.float32))
    dk = jnp.einsum("bts,btd->bsd", ds, q.astype(jnp.float32))
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def gqa_flash(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
              interpret: bool = True, blk_q: int = 128, blk_k: int = 128):
    """GQA convenience wrapper: q (B,T,H,hd), k/v (B,T,G,hd) -> (B,T,H,hd)."""
    B, T, H, hd = q.shape
    G = k.shape[2]
    rep = H // G
    kx = jnp.repeat(k, rep, axis=2)
    vx = jnp.repeat(v, rep, axis=2)

    def flat(t):
        return jnp.moveaxis(t, 2, 1).reshape(B * H, T, hd)

    o = flash_attention(flat(q), flat(kx), flat(vx), causal, blk_q, blk_k,
                        interpret)
    return jnp.moveaxis(o.reshape(B, H, T, hd), 1, 2)
