"""Fused gradient-statistics Pallas kernel (TPU target).

FedAdp's angle needs (<g, g_i>, ||g||^2, ||g_i||^2) over the flattened
parameter vector — O(P) elements streamed once. XLA emits three separate
reduce fusions (three HBM passes); this kernel computes all three in a
single HBM->VMEM pass (memory-bound: arithmetic intensity ~3 FLOP/8 B).

Tiling: inputs are viewed as (M, 128) f32 and the grid walks row-blocks of
ROWS x 128 (ROWS*128*4 B = 256 KiB per operand in VMEM). TPU executes grid
steps of a sequential dimension in order on the same core, so the (1, 1)
output blocks act as accumulators across steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
ROWS = 512  # 512*128*4 B = 256 KiB per input block


def _kernel(a_ref, b_ref, dot_ref, na_ref, nb_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        dot_ref[0, 0] = 0.0
        na_ref[0, 0] = 0.0
        nb_ref[0, 0] = 0.0

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    dot_ref[0, 0] += jnp.sum(a * b)
    na_ref[0, 0] += jnp.sum(a * a)
    nb_ref[0, 0] += jnp.sum(b * b)


@functools.partial(jax.jit, static_argnames=("interpret",))
def grad_dot_stats(a: jax.Array, b: jax.Array, *, interpret: bool = True):
    """(<a,b>, ||a||^2, ||b||^2) for equally-shaped arrays, f32 accumulate.

    interpret=True runs the kernel body on CPU (container has no TPU);
    on real hardware pass interpret=False.
    """
    assert a.shape == b.shape
    af = a.reshape(-1)
    bf = b.reshape(-1)
    n = af.shape[0]
    block = ROWS * LANE
    pad = (-n) % block
    if pad:
        af = jnp.concatenate([af, jnp.zeros((pad,), af.dtype)])
        bf = jnp.concatenate([bf, jnp.zeros((pad,), bf.dtype)])
    m = af.shape[0] // LANE
    a2 = af.reshape(m, LANE)
    b2 = bf.reshape(m, LANE)

    out_shape = tuple(jax.ShapeDtypeStruct((1, 1), jnp.float32) for _ in range(3))
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    dot, na, nb = pl.pallas_call(
        _kernel,
        grid=(m // ROWS,),
        in_specs=[
            pl.BlockSpec((ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, LANE), lambda i: (i, 0)),
        ],
        out_specs=(scalar_spec, scalar_spec, scalar_spec),
        out_shape=out_shape,
        interpret=interpret,
    )(a2, b2)
    return dot[0, 0], na[0, 0], nb[0, 0]
