"""Fused per-round angle-statistics Pallas kernel (TPU target).

FedAdp's contribution measurement (paper Eqs. 8-11) needs, per round:
  dots[k] = <x_k, g>    — K angle numerators
  sqs[k]  = ||x_k||^2   — K client squared norms
  sqg     = ||g||^2     — the global-gradient squared norm
over the flat (K, N) client-delta buffer x and the (N,) global delta g.
Computed separately (`batched_dot` + K sqnorm reductions + one sqnorm)
that is three HBM passes over x; this kernel streams each (K_TILE, ROWS,
128) tile through VMEM once and emits all 2K+1 statistics — a single HBM
pass over x.

The client axis is chunked like `weighted_agg`: the grid is (client
chunks, lane tiles) with the lane dimension minor, so each chunk's
(K_TILE, 1) output blocks accumulate across consecutive lane steps, and
sqg accumulates only on the first chunk (g is re-streamed per chunk but
must be counted once). Any K is served; the former trace-time MAX_K
rejection is gone.

An optional (N,) 0/1 segment mask restricts the statistics to a leaf
subset (the `angle_filter="dense_only"` MoE filter) without materializing
masked copies of x or g: the mask tile rides along and is applied in-VMEM.

`interpret=True` runs the identical kernel body on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# tile geometry and client-chunk size are shared with weighted_agg — the
# (K_TILE, ROWS, LANE) x-tile here fits the same VMEM envelope.
from repro.kernels.weighted_agg import (
    K_TILE,  # noqa: F401  (re-exported: callers size shards against it)
    LANE,
    ROWS,
    _k_chunks,
    _pad_axis0,
    _pad_lanes,
)


def _stats_kernel(x_ref, g_ref, dots_ref, sqs_ref, sqg_ref):
    kc, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        dots_ref[...] = jnp.zeros_like(dots_ref)
        sqs_ref[...] = jnp.zeros_like(sqs_ref)

    @pl.when((kc == 0) & (i == 0))
    def _init_g():
        sqg_ref[0, 0] = 0.0

    x = x_ref[...].astype(jnp.float32)  # (KT, ROWS, LANE)
    g = g_ref[...].astype(jnp.float32)  # (ROWS, LANE)
    dots_ref[...] += jnp.sum(x * g[None], axis=(1, 2))[:, None]
    sqs_ref[...] += jnp.sum(x * x, axis=(1, 2))[:, None]

    @pl.when(kc == 0)  # g repeats per client chunk; count it once
    def _accum_g():
        sqg_ref[0, 0] += jnp.sum(g * g)


def _stats_kernel_masked(x_ref, g_ref, m_ref, dots_ref, sqs_ref, sqg_ref):
    kc, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        dots_ref[...] = jnp.zeros_like(dots_ref)
        sqs_ref[...] = jnp.zeros_like(sqs_ref)

    @pl.when((kc == 0) & (i == 0))
    def _init_g():
        sqg_ref[0, 0] = 0.0

    m = m_ref[...].astype(jnp.float32)  # (ROWS, LANE) in {0, 1}
    x = x_ref[...].astype(jnp.float32) * m[None]
    g = g_ref[...].astype(jnp.float32) * m
    dots_ref[...] += jnp.sum(x * g[None], axis=(1, 2))[:, None]
    sqs_ref[...] += jnp.sum(x * x, axis=(1, 2))[:, None]

    @pl.when(kc == 0)
    def _accum_g():
        sqg_ref[0, 0] += jnp.sum(g * g)


@functools.partial(jax.jit, static_argnames=("interpret",))
def round_stats(x: jax.Array, g: jax.Array, mask: jax.Array | None = None,
                *, interpret: bool = True):
    """(dots (K,), sqnorms (K,), sqg ()) in one pass over x: (K, N), g: (N,).

    mask, if given, is an (N,) 0/1 vector; statistics are computed over the
    masked subspace (mask is idempotent, so only one multiply per operand).
    Accumulates in f32 regardless of input dtype. Any K: the client axis is
    zero-padded to a chunk multiple and gridded (zero rows add zero stats).
    """
    K, n = x.shape
    tile, kp = _k_chunks(K)
    block = ROWS * LANE
    x = _pad_axis0(_pad_lanes(x, block), kp)
    g = _pad_lanes(g, block)
    if mask is not None:
        mask = _pad_lanes(mask, block)
    m = x.shape[1] // LANE
    x3 = x.reshape(kp, m, LANE)
    g2 = g.reshape(m, LANE)

    tile_spec = pl.BlockSpec((ROWS, LANE), lambda kc, i: (i, 0))
    in_specs = [
        pl.BlockSpec((tile, ROWS, LANE), lambda kc, i: (kc, i, 0)),
        tile_spec,
    ]
    operands = [x3, g2]
    kernel = _stats_kernel
    if mask is not None:
        in_specs.append(tile_spec)
        operands.append(mask.reshape(m, LANE))
        kernel = _stats_kernel_masked

    kvec_spec = pl.BlockSpec((tile, 1), lambda kc, i: (kc, 0))
    dots, sqs, sqg = pl.pallas_call(
        kernel,
        grid=(kp // tile, m // ROWS),
        in_specs=in_specs,
        out_specs=(kvec_spec, kvec_spec,
                   pl.BlockSpec((1, 1), lambda kc, i: (0, 0))),
        out_shape=(
            jax.ShapeDtypeStruct((kp, 1), jnp.float32),
            jax.ShapeDtypeStruct((kp, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        interpret=interpret,
    )(*operands)
    return dots[:K, 0], sqs[:K, 0], sqg[0, 0]
