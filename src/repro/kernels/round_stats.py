"""Fused per-round angle-statistics Pallas kernel (TPU target).

FedAdp's contribution measurement (paper Eqs. 8-11) needs, per round:
  dots[k] = <x_k, g>    — K angle numerators
  sqs[k]  = ||x_k||^2   — K client squared norms
  sqg     = ||g||^2     — the global-gradient squared norm
over the flat (K, N) client-delta buffer x and the (N,) global delta g.
Computed separately (`batched_dot` + K sqnorm reductions + one sqnorm)
that is three HBM passes over x; this kernel streams each (K, ROWS, 128)
tile through VMEM once and emits all 2K+1 statistics — a single HBM pass.

An optional (N,) 0/1 segment mask restricts the statistics to a leaf
subset (the `angle_filter="dense_only"` MoE filter) without materializing
masked copies of x or g: the mask tile rides along and is applied in-VMEM.

Grid steps of the sequential dimension run in order on one TPU core, so
the small output blocks act as accumulators across steps (same pattern as
`grad_dot.py`). `interpret=True` runs the identical kernel body on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# tile geometry and the K budget derived from it are shared with
# weighted_agg — the (K, ROWS, LANE) x-tile here must fit the same VMEM
# envelope check_k enforces.
from repro.kernels.weighted_agg import LANE, MAX_K, ROWS, check_k


def _stats_kernel(x_ref, g_ref, dots_ref, sqs_ref, sqg_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        dots_ref[...] = jnp.zeros_like(dots_ref)
        sqs_ref[...] = jnp.zeros_like(sqs_ref)
        sqg_ref[0, 0] = 0.0

    x = x_ref[...].astype(jnp.float32)  # (K, ROWS, LANE)
    g = g_ref[...].astype(jnp.float32)  # (ROWS, LANE)
    dots_ref[...] += jnp.sum(x * g[None], axis=(1, 2))[:, None]
    sqs_ref[...] += jnp.sum(x * x, axis=(1, 2))[:, None]
    sqg_ref[0, 0] += jnp.sum(g * g)


def _stats_kernel_masked(x_ref, g_ref, m_ref, dots_ref, sqs_ref, sqg_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        dots_ref[...] = jnp.zeros_like(dots_ref)
        sqs_ref[...] = jnp.zeros_like(sqs_ref)
        sqg_ref[0, 0] = 0.0

    m = m_ref[...].astype(jnp.float32)  # (ROWS, LANE) in {0, 1}
    x = x_ref[...].astype(jnp.float32) * m[None]
    g = g_ref[...].astype(jnp.float32) * m
    dots_ref[...] += jnp.sum(x * g[None], axis=(1, 2))[:, None]
    sqs_ref[...] += jnp.sum(x * x, axis=(1, 2))[:, None]
    sqg_ref[0, 0] += jnp.sum(g * g)


@functools.partial(jax.jit, static_argnames=("interpret",))
def round_stats(x: jax.Array, g: jax.Array, mask: jax.Array | None = None,
                *, interpret: bool = True):
    """(dots (K,), sqnorms (K,), sqg ()) in one pass over x: (K, N), g: (N,).

    mask, if given, is an (N,) 0/1 vector; statistics are computed over the
    masked subspace (mask is idempotent, so only one multiply per operand).
    Accumulates in f32 regardless of input dtype.
    """
    K, n = x.shape
    check_k(K)
    block = ROWS * LANE
    pad = (-n) % block
    if pad:
        x = jnp.concatenate([x, jnp.zeros((K, pad), x.dtype)], axis=1)
        g = jnp.concatenate([g, jnp.zeros((pad,), g.dtype)])
        if mask is not None:
            mask = jnp.concatenate([mask, jnp.zeros((pad,), mask.dtype)])
    m = x.shape[1] // LANE
    x3 = x.reshape(K, m, LANE)
    g2 = g.reshape(m, LANE)

    tile_spec = pl.BlockSpec((ROWS, LANE), lambda i: (i, 0))
    in_specs = [pl.BlockSpec((K, ROWS, LANE), lambda i: (0, i, 0)), tile_spec]
    operands = [x3, g2]
    kernel = _stats_kernel
    if mask is not None:
        in_specs.append(tile_spec)
        operands.append(mask.reshape(m, LANE))
        kernel = _stats_kernel_masked

    kvec_spec = pl.BlockSpec((K, 1), lambda i: (0, 0))
    dots, sqs, sqg = pl.pallas_call(
        kernel,
        grid=(m // ROWS,),
        in_specs=in_specs,
        out_specs=(kvec_spec, kvec_spec, pl.BlockSpec((1, 1), lambda i: (0, 0))),
        out_shape=(
            jax.ShapeDtypeStruct((K, 1), jnp.float32),
            jax.ShapeDtypeStruct((K, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        interpret=interpret,
    )(*operands)
    return dots[:, 0], sqs[:, 0], sqg[0, 0]
