"""Fused per-round angle-statistics Pallas kernel (TPU target).

FedAdp's contribution measurement (paper Eqs. 8-11) needs, per round:
  dots[k] = <x_k, g>    — K angle numerators
  sqs[k]  = ||x_k||^2   — K client squared norms
  sqg     = ||g||^2     — the global-gradient squared norm
over the flat (K, N) client-delta buffer x and the (N,) global delta g.
Computed separately (`batched_dot` + K sqnorm reductions + one sqnorm)
that is three HBM passes over x; this kernel streams each (K_TILE, ROWS,
128) tile through VMEM once and emits all 2K+1 statistics — a single HBM
pass over x.

The client axis is chunked like `weighted_agg`: the grid is (client
chunks, lane tiles) with the lane dimension minor, so each chunk's
(K_TILE, 1) output blocks accumulate across consecutive lane steps, and
sqg accumulates only on the first chunk (g is re-streamed per chunk but
must be counted once). Any K is served; a ragged tail chunk (K % K_TILE
!= 0) is bounds-masked in-kernel, so the buffer is never copied to a
zero-padded staging array.

An optional (N,) 0/1 segment mask restricts the statistics to a leaf
subset (the `angle_filter="dense_only"` MoE filter) without materializing
masked copies of x or g: the mask tile rides along and is applied in-VMEM.

`round_stats_q` is the quantized-transport path (repro.transport): x
arrives as int8 wire values plus one f32 scale per (client, ROWS*LANE
chunk); dequantization happens in-register on the loaded tile, so the
statistics stay one HBM pass over ~4x fewer bytes. g stays f32 — it is
server-side state and never crosses the wire.

`round_stats_q4` is the int4 packed path: each physical byte tile holds
two logical chunks (low/high nibble planes of consecutive element
pairs), scales are grouped (2*CHUNK/group_size groups per tile, expanded
in-register), and the server-side g / mask vectors ride along as even/odd
(ROWS, LANE) views so every nibble pairs with its own g element without
ever interleaving the wire buffer — one HBM pass over ~8x fewer bytes.

`interpret=True` runs the identical kernel body on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# tile geometry and client-chunk size are shared with weighted_agg — the
# (K_TILE, ROWS, LANE) x-tile here fits the same VMEM envelope.
from repro.kernels.weighted_agg import (
    K_TILE,  # noqa: F401  (re-exported: callers size shards against it)
    LANE,
    ROWS,
    _expand_group_scales,
    _k_chunks,
    _mask_tail_rows,
    _pad_lanes,
    _row_block,
    _unpack_nibbles,
    _use_fallback,
)


def _stats_kernel(x_ref, g_ref, dots_ref, sqs_ref, sqg_ref, *, k, tile):
    kc, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        dots_ref[...] = jnp.zeros_like(dots_ref)
        sqs_ref[...] = jnp.zeros_like(sqs_ref)

    @pl.when((kc == 0) & (i == 0))
    def _init_g():
        sqg_ref[0, 0] = 0.0

    x = _mask_tail_rows(x_ref[...].astype(jnp.float32), kc, k=k, tile=tile)
    g = g_ref[...].astype(jnp.float32)  # (ROWS, LANE)
    dots_ref[...] += jnp.sum(x * g[None], axis=(1, 2))[:, None]
    sqs_ref[...] += jnp.sum(x * x, axis=(1, 2))[:, None]

    @pl.when(kc == 0)  # g repeats per client chunk; count it once
    def _accum_g():
        sqg_ref[0, 0] += jnp.sum(g * g)


def _stats_kernel_masked(x_ref, g_ref, m_ref, dots_ref, sqs_ref, sqg_ref,
                         *, k, tile):
    kc, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        dots_ref[...] = jnp.zeros_like(dots_ref)
        sqs_ref[...] = jnp.zeros_like(sqs_ref)

    @pl.when((kc == 0) & (i == 0))
    def _init_g():
        sqg_ref[0, 0] = 0.0

    m = m_ref[...].astype(jnp.float32)  # (ROWS, LANE) in {0, 1}
    x = _mask_tail_rows(x_ref[...].astype(jnp.float32) * m[None], kc,
                        k=k, tile=tile)
    g = g_ref[...].astype(jnp.float32) * m
    dots_ref[...] += jnp.sum(x * g[None], axis=(1, 2))[:, None]
    sqs_ref[...] += jnp.sum(x * x, axis=(1, 2))[:, None]

    @pl.when(kc == 0)
    def _accum_g():
        sqg_ref[0, 0] += jnp.sum(g * g)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "min_kernel_elems"))
def round_stats(x: jax.Array, g: jax.Array, mask: jax.Array | None = None,
                *, interpret: bool = True, min_kernel_elems=None):
    """(dots (K,), sqnorms (K,), sqg ()) in one pass over x: (K, N), g: (N,).

    mask, if given, is an (N,) 0/1 vector; statistics are computed over the
    masked subspace (mask is idempotent, so only one multiply per operand).
    Accumulates in f32 regardless of input dtype. Any K: the client axis is
    gridded in chunks, the ragged tail chunk bounds-masked in-kernel.
    Buffers below `min_kernel_elems` elements (default SMALL_ELEMS; 0
    forces Pallas) compute as plain XLA reductions.
    """
    K, n = x.shape
    if _use_fallback(K, n, min_kernel_elems):
        xf = x.astype(jnp.float32)
        gf = g.astype(jnp.float32)
        if mask is not None:
            mf = mask.astype(jnp.float32)
            xf = xf * mf[None]
            gf = gf * mf
        return xf @ gf, jnp.sum(xf * xf, axis=1), jnp.dot(gf, gf)
    tile, kp = _k_chunks(K)
    rows = _row_block(n)
    block = rows * LANE
    x = _pad_lanes(x, block)
    g = _pad_lanes(g, block)
    if mask is not None:
        mask = _pad_lanes(mask, block)
    m = x.shape[1] // LANE
    x3 = x.reshape(K, m, LANE)
    g2 = g.reshape(m, LANE)

    tile_spec = pl.BlockSpec((rows, LANE), lambda kc, i: (i, 0))
    in_specs = [
        pl.BlockSpec((tile, rows, LANE), lambda kc, i: (kc, i, 0)),
        tile_spec,
    ]
    operands = [x3, g2]
    kernel = _stats_kernel
    if mask is not None:
        in_specs.append(tile_spec)
        operands.append(mask.reshape(m, LANE))
        kernel = _stats_kernel_masked

    kvec_spec = pl.BlockSpec((tile, 1), lambda kc, i: (kc, 0))
    dots, sqs, sqg = pl.pallas_call(
        functools.partial(kernel, k=K, tile=tile),
        grid=(kp // tile, m // rows),
        in_specs=in_specs,
        out_specs=(kvec_spec, kvec_spec,
                   pl.BlockSpec((1, 1), lambda kc, i: (0, 0))),
        out_shape=(
            jax.ShapeDtypeStruct((kp, 1), jnp.float32),
            jax.ShapeDtypeStruct((kp, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        interpret=interpret,
    )(*operands)
    return dots[:K, 0], sqs[:K, 0], sqg[0, 0]


def _stats_q4_kernel(x_ref, s_ref, ge_ref, go_ref, dots_ref, sqs_ref,
                     sqg_ref, *, k, tile, gs2):
    kc, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        dots_ref[...] = jnp.zeros_like(dots_ref)
        sqs_ref[...] = jnp.zeros_like(sqs_ref)

    @pl.when((kc == 0) & (i == 0))
    def _init_g():
        sqg_ref[0, 0] = 0.0

    lo, hi = _unpack_nibbles(x_ref[...])
    sexp = _expand_group_scales(s_ref[...], gs2)  # (KT, ROWS, LANE)
    xlo = _mask_tail_rows(lo.astype(jnp.float32) * sexp, kc, k=k, tile=tile)
    xhi = _mask_tail_rows(hi.astype(jnp.float32) * sexp, kc, k=k, tile=tile)
    ge = ge_ref[...].astype(jnp.float32)  # (ROWS, LANE) — g[0::2]
    go = go_ref[...].astype(jnp.float32)  # (ROWS, LANE) — g[1::2]
    dots_ref[...] += (jnp.sum(xlo * ge[None], axis=(1, 2))
                      + jnp.sum(xhi * go[None], axis=(1, 2)))[:, None]
    sqs_ref[...] += (jnp.sum(xlo * xlo, axis=(1, 2))
                     + jnp.sum(xhi * xhi, axis=(1, 2)))[:, None]

    @pl.when(kc == 0)  # g repeats per client chunk; count it once
    def _accum_g():
        sqg_ref[0, 0] += jnp.sum(ge * ge) + jnp.sum(go * go)


def _stats_q4_kernel_masked(x_ref, s_ref, ge_ref, go_ref, me_ref, mo_ref,
                            dots_ref, sqs_ref, sqg_ref, *, k, tile, gs2):
    kc, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        dots_ref[...] = jnp.zeros_like(dots_ref)
        sqs_ref[...] = jnp.zeros_like(sqs_ref)

    @pl.when((kc == 0) & (i == 0))
    def _init_g():
        sqg_ref[0, 0] = 0.0

    lo, hi = _unpack_nibbles(x_ref[...])
    sexp = _expand_group_scales(s_ref[...], gs2)
    me = me_ref[...].astype(jnp.float32)  # (ROWS, LANE) in {0, 1}
    mo = mo_ref[...].astype(jnp.float32)
    xlo = _mask_tail_rows(lo.astype(jnp.float32) * sexp * me[None], kc,
                          k=k, tile=tile)
    xhi = _mask_tail_rows(hi.astype(jnp.float32) * sexp * mo[None], kc,
                          k=k, tile=tile)
    ge = ge_ref[...].astype(jnp.float32) * me
    go = go_ref[...].astype(jnp.float32) * mo
    dots_ref[...] += (jnp.sum(xlo * ge[None], axis=(1, 2))
                      + jnp.sum(xhi * go[None], axis=(1, 2)))[:, None]
    sqs_ref[...] += (jnp.sum(xlo * xlo, axis=(1, 2))
                     + jnp.sum(xhi * xhi, axis=(1, 2)))[:, None]

    @pl.when(kc == 0)
    def _accum_g():
        sqg_ref[0, 0] += jnp.sum(ge * ge) + jnp.sum(go * go)


def _even_odd_views(vec: jax.Array, cols: int, m: int):
    """Pad an (n,) server-side vector to 2*cols logical elements and split
    into the (m, LANE) even/odd views the nibble planes pair with."""
    pad = 2 * cols - vec.shape[0]
    if pad:
        vec = jnp.pad(vec, (0, pad))
    return vec[0::2].reshape(m, LANE), vec[1::2].reshape(m, LANE)


@functools.partial(jax.jit, static_argnames=("group_size", "interpret"))
def round_stats_q4(values: jax.Array, scales: jax.Array, g: jax.Array,
                   mask: jax.Array | None = None, *, group_size: int,
                   interpret: bool = True):
    """`round_stats` over the int4 packed wire buffer, dequant in-register.

    values: (K, ceil(n/2)) int8 packed (two int4 params per byte, low
    nibble first); scales: (K, ceil(n/group_size)) f32 grouped dequant
    multipliers (repro.transport int4 layout). g: (n,) f32 (server-side,
    never quantized); mask likewise. Matches
    round_stats(dequantize(int4 wire), g, mask) to f32 accumulation
    order. group_size must be even and divide CHUNK = ROWS*LANE
    (transport.validate_group_size): tiles cover whole groups and both
    nibbles of a byte share one scale. Zero padding bytes dequantize to
    exactly zero; the ragged tail client chunk is bounds-masked, so
    out-of-range scale reads are select-zeroed with the rows they scale.
    """
    K, nb = values.shape
    n = g.shape[0]
    assert nb == -(-n // 2), (nb, n)
    gs2 = group_size // 2
    tile, kp = _k_chunks(K)
    x = _pad_lanes(values, ROWS * LANE)
    cols = x.shape[1]
    m = cols // LANE
    gp = cols // gs2
    gt = (ROWS * LANE) // gs2
    assert scales.shape[0] == K and scales.shape[1] <= gp, (scales.shape, gp)
    sp = jnp.pad(scales.astype(jnp.float32),
                 ((0, 0), (0, gp - scales.shape[1])), constant_values=1.0)
    x3 = x.reshape(K, m, LANE)
    ge2, go2 = _even_odd_views(g.astype(jnp.float32), cols, m)

    tile_spec = pl.BlockSpec((ROWS, LANE), lambda kc, i: (i, 0))
    in_specs = [
        pl.BlockSpec((tile, ROWS, LANE), lambda kc, i: (kc, i, 0)),
        pl.BlockSpec((tile, gt), lambda kc, i: (kc, i)),
        tile_spec,
        tile_spec,
    ]
    operands = [x3, sp, ge2, go2]
    kernel = _stats_q4_kernel
    if mask is not None:
        me2, mo2 = _even_odd_views(mask.astype(jnp.float32), cols, m)
        in_specs += [tile_spec, tile_spec]
        operands += [me2, mo2]
        kernel = _stats_q4_kernel_masked

    kvec_spec = pl.BlockSpec((tile, 1), lambda kc, i: (kc, 0))
    dots, sqs, sqg = pl.pallas_call(
        functools.partial(kernel, k=K, tile=tile, gs2=gs2),
        grid=(kp // tile, m // ROWS),
        in_specs=in_specs,
        out_specs=(kvec_spec, kvec_spec,
                   pl.BlockSpec((1, 1), lambda kc, i: (0, 0))),
        out_shape=(
            jax.ShapeDtypeStruct((kp, 1), jnp.float32),
            jax.ShapeDtypeStruct((kp, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        interpret=interpret,
    )(*operands)
    return dots[:K, 0], sqs[:K, 0], sqg[0, 0]


def _stats_q_kernel(x_ref, s_ref, g_ref, dots_ref, sqs_ref, sqg_ref,
                    *, k, tile):
    kc, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        dots_ref[...] = jnp.zeros_like(dots_ref)
        sqs_ref[...] = jnp.zeros_like(sqs_ref)

    @pl.when((kc == 0) & (i == 0))
    def _init_g():
        sqg_ref[0, 0] = 0.0

    # in-register dequant: one f32 scale per (client, tile)
    s = s_ref[...]  # (KT, 1)
    x = _mask_tail_rows(x_ref[...].astype(jnp.float32) * s[:, :, None], kc,
                        k=k, tile=tile)
    g = g_ref[...].astype(jnp.float32)
    dots_ref[...] += jnp.sum(x * g[None], axis=(1, 2))[:, None]
    sqs_ref[...] += jnp.sum(x * x, axis=(1, 2))[:, None]

    @pl.when(kc == 0)
    def _accum_g():
        sqg_ref[0, 0] += jnp.sum(g * g)


def _stats_q_kernel_masked(x_ref, s_ref, g_ref, m_ref, dots_ref, sqs_ref,
                           sqg_ref, *, k, tile):
    kc, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        dots_ref[...] = jnp.zeros_like(dots_ref)
        sqs_ref[...] = jnp.zeros_like(sqs_ref)

    @pl.when((kc == 0) & (i == 0))
    def _init_g():
        sqg_ref[0, 0] = 0.0

    s = s_ref[...]  # (KT, 1)
    m = m_ref[...].astype(jnp.float32)  # (ROWS, LANE)
    x = _mask_tail_rows(
        x_ref[...].astype(jnp.float32) * s[:, :, None] * m[None], kc,
        k=k, tile=tile)
    g = g_ref[...].astype(jnp.float32) * m
    dots_ref[...] += jnp.sum(x * g[None], axis=(1, 2))[:, None]
    sqs_ref[...] += jnp.sum(x * x, axis=(1, 2))[:, None]

    @pl.when(kc == 0)
    def _accum_g():
        sqg_ref[0, 0] += jnp.sum(g * g)


@functools.partial(jax.jit, static_argnames=("interpret",))
def round_stats_q(values: jax.Array, scales: jax.Array, g: jax.Array,
                  mask: jax.Array | None = None, *, interpret: bool = True):
    """`round_stats` over the int8 wire buffer, dequant fused in-register.

    values: (K, N) int8; scales: (K, ceil(N / (ROWS*LANE))) f32 — the
    repro.transport per-(client, chunk) layout, one scale per grid tile.
    g: (N,) f32 (server-side, never quantized). Matches
    round_stats(dequantize(values, scales), g, mask) to f32 accumulation
    order. Lane-tail zero padding needs no scale handling (int8 zeros
    dequantize to zero); the ragged tail client chunk is bounds-masked, so
    out-of-range scale reads are select-zeroed with the rows they scale.
    """
    K, n = values.shape
    tile, kp = _k_chunks(K)
    block = ROWS * LANE
    x = _pad_lanes(values, block)
    g = _pad_lanes(g, block)
    if mask is not None:
        mask = _pad_lanes(mask, block)
    m = x.shape[1] // LANE
    c = m // ROWS
    assert scales.shape == (K, c), (scales.shape, (K, c))
    x3 = x.reshape(K, m, LANE)
    g2 = g.reshape(m, LANE)

    tile_spec = pl.BlockSpec((ROWS, LANE), lambda kc, i: (i, 0))
    in_specs = [
        pl.BlockSpec((tile, ROWS, LANE), lambda kc, i: (kc, i, 0)),
        pl.BlockSpec((tile, 1), lambda kc, i: (kc, i)),
        tile_spec,
    ]
    operands = [x3, scales.astype(jnp.float32), g2]
    kernel = _stats_q_kernel
    if mask is not None:
        in_specs.append(tile_spec)
        operands.append(mask.reshape(m, LANE))
        kernel = _stats_q_kernel_masked

    kvec_spec = pl.BlockSpec((tile, 1), lambda kc, i: (kc, 0))
    dots, sqs, sqg = pl.pallas_call(
        functools.partial(kernel, k=K, tile=tile),
        grid=(kp // tile, m // ROWS),
        in_specs=in_specs,
        out_specs=(kvec_spec, kvec_spec,
                   pl.BlockSpec((1, 1), lambda kc, i: (0, 0))),
        out_shape=(
            jax.ShapeDtypeStruct((kp, 1), jnp.float32),
            jax.ShapeDtypeStruct((kp, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        interpret=interpret,
    )(*operands)
    return dots[:K, 0], sqs[:K, 0], sqg[0, 0]
