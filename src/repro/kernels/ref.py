"""Pure-jnp oracles for the Pallas kernels (tests assert_allclose vs these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grad_dot_stats(a: jax.Array, b: jax.Array):
    af = a.reshape(-1).astype(jnp.float32)
    bf = b.reshape(-1).astype(jnp.float32)
    return jnp.dot(af, bf), jnp.dot(af, af), jnp.dot(bf, bf)


def weighted_agg(w: jax.Array, x: jax.Array):
    return jnp.sum(
        w.astype(jnp.float32)[:, None] * x.astype(jnp.float32), axis=0
    ).astype(x.dtype)


def batched_dot(x: jax.Array, g: jax.Array):
    return x.astype(jnp.float32) @ g.astype(jnp.float32)


def round_stats(x: jax.Array, g: jax.Array, mask: jax.Array | None = None):
    """(dots (K,), sqnorms (K,), sqg ()) over x (K, N), g (N,)."""
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    if mask is not None:
        mf = mask.astype(jnp.float32)
        xf = xf * mf[None]
        gf = gf * mf
    return xf @ gf, jnp.sum(xf * xf, axis=1), jnp.dot(gf, gf)


def _dequant(values: jax.Array, scales: jax.Array) -> jax.Array:
    """(K, N) f32 from int8 wire values + per-chunk scales — delegates to
    the transport layer's own dequantize so the oracle always verifies the
    fused kernels against the ACTUAL wire semantics (a local re-derivation
    could drift if the chunk layout ever changes)."""
    from repro.transport.quantize import QuantizedDelta, dequantize

    return dequantize(QuantizedDelta(values, scales))


def weighted_agg_q(w: jax.Array, values: jax.Array, scales: jax.Array):
    """Dequantize-then-f32 oracle for the fused weighted_agg_q kernel."""
    x = _dequant(values, scales)
    return jnp.sum(w.astype(jnp.float32)[:, None] * x, axis=0)


def round_stats_q(values: jax.Array, scales: jax.Array, g: jax.Array,
                  mask: jax.Array | None = None):
    """Dequantize-then-f32 oracle for the fused round_stats_q kernel."""
    return round_stats(_dequant(values, scales), g, mask)


def _dequant4(values: jax.Array, scales: jax.Array, n: int,
              group_size: int) -> jax.Array:
    """(K, n) f32 from the int4 packed wire (nibble pairs + grouped
    scales) — delegates to the transport layer's own dequantize, like
    `_dequant`, so the oracle tracks the ACTUAL wire semantics."""
    from repro.transport.quantize import QuantizedDelta, dequantize

    return dequantize(QuantizedDelta(values, scales, "int4", n, group_size))


def weighted_agg_q4(w: jax.Array, values: jax.Array, scales: jax.Array, *,
                    n: int, group_size: int):
    """Dequantize-then-f32 oracle for the fused weighted_agg_q4 kernel."""
    x = _dequant4(values, scales, n, group_size)
    return jnp.sum(w.astype(jnp.float32)[:, None] * x, axis=0)


def round_stats_q4(values: jax.Array, scales: jax.Array, g: jax.Array,
                   mask: jax.Array | None = None, *, group_size: int):
    """Dequantize-then-f32 oracle for the fused round_stats_q4 kernel."""
    return round_stats(_dequant4(values, scales, g.shape[0], group_size),
                       g, mask)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True):
    """Naive softmax attention oracle. q/k/v (BH, T, d)."""
    T = q.shape[1]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(T)[None, :] <= jnp.arange(T)[:, None]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsd->btd", p, v.astype(jnp.float32)).astype(q.dtype)
