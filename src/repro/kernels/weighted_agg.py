"""Fused K-way weighted delta aggregation Pallas kernel (TPU target).

FedAdp's global update is y = sum_k w_k * x_k over K client deltas
(Eq. 4/11). A naive implementation is K scaled-add passes (K reads of y);
this kernel streams (K_TILE, ROWS, 128) tiles through VMEM and writes
each y tile once — a single HBM pass over the stacked deltas.

The client axis is CHUNKED, not whole-K tiled: the grid walks
ceil(K / K_TILE) client chunks per output tile and accumulates partial
sums into the revisited f32 output block (sequential grid steps run in
order on one TPU core, so revisited output blocks act as accumulators —
same pattern as `grad_dot.py`). Any K is served with a bounded VMEM
envelope. Ragged K (K % K_TILE != 0) is handled by an IN-KERNEL bounds
mask on the tail chunk — the (K, N) buffer is never copied to a padded
staging buffer (the former `jnp.concatenate` zero-pad is gone; only the
O(K) weight vector is still padded, which costs nothing).

`weighted_agg_q` is the quantized-transport variant: it reads int8 wire
values plus one f32 scale per (client, CHUNK)-tile and dequantizes
in-register, so aggregation over a compressed uplink stays a single HBM
pass that moves ~4x fewer bytes (see repro.transport).

`weighted_agg_q4` extends that to the int4 packed wire: each physical
(ROWS, LANE) byte tile holds TWO logical value chunks (low/high nibbles
of consecutive element pairs), and the per-(client, group) scales are no
longer 1:1 with tiles — a tile covers 2*CHUNK/group_size groups, expanded
in-register by a static repeat. Both nibbles unpack in-register (shift /
mask / sign-extend on the int32 upcast), so aggregation over the int4
uplink is a single HBM pass over ~8x fewer bytes than f32. The kernel
emits separate even/odd accumulators (one per nibble plane) that the
wrapper interleaves back to logical order — an O(N) f32 shuffle on the
OUTPUT, never a second pass over the wire buffer.

Also provides `batched_dot`: u_k = <x_k, g> for all K clients in one pass
(the per-client angle numerators), sharing the same tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
ROWS = 128  # per-client block: 128*128*4 B = 64 KiB
# Client-axis chunk: 32*128*128*4 B = 2 MiB per x tile — small enough to
# leave VMEM room for double buffering on a ~16 MiB core. K <= K_TILE runs
# as one chunk of size K; larger K is gridded, with the ragged tail chunk
# bounds-masked inside the kernel (no buffer copy).
K_TILE = 32

# Below this many buffer elements (K * N) the f32 wrappers dispatch to the
# equivalent jnp/XLA expression instead of pallas_call: the kernel's fixed
# launch cost (~1.3 ms in interpret mode, and still a full grid setup
# compiled) dwarfs the arithmetic of a tiny round — the measured source of
# the K=8, d=1024 flat-vs-tree cliff. The fallback computes the same f32
# reduction (different accumulation order, same 1e-5 contract as the
# kernels vs their oracles). Quantized wrappers are exempt: the wire's
# per-CHUNK scale layout is a transport contract, and quantized buffers
# only arise at sizes where the kernels already win.
SMALL_ELEMS = 1 << 17


def _k_chunks(k: int) -> tuple[int, int]:
    """(chunk size, padded K) for gridding the client axis."""
    tile = min(k, K_TILE)
    return tile, ((k + tile - 1) // tile) * tile


def _row_block(n: int) -> int:
    """Sublane block for the unquantized kernels: shrink ROWS for narrow
    buffers so N pads to rows*LANE instead of ROWS*LANE (a d=1024 row
    would otherwise pad 16x). Power of two in [8, ROWS]; 8 sublanes is
    the f32 minimum tile. The quantized kernels keep ROWS — their scale
    chunk CHUNK = ROWS*LANE is the transport wire layout."""
    lanes = -(-n // LANE)
    r = 8
    while r < ROWS and r < lanes:
        r *= 2
    return r


def _use_fallback(k: int, n: int, min_kernel_elems) -> bool:
    """True when (k, n) is below the Pallas break-even point.

    `min_kernel_elems=None` uses SMALL_ELEMS; 0 forces the kernel path
    (tests pin Pallas coverage with it); a custom threshold tunes the
    break-even per deployment."""
    lim = SMALL_ELEMS if min_kernel_elems is None else min_kernel_elems
    return k * n < lim


def _pad_axis0(x: jax.Array, kp: int) -> jax.Array:
    """Zero-pad axis 0 to kp rows — used only for O(K) weight/scale
    vectors; the (K, N) buffers stay unpadded (in-kernel tail mask)."""
    k = x.shape[0]
    if kp == k:
        return x
    return jnp.concatenate([x, jnp.zeros((kp - k,) + x.shape[1:], x.dtype)])


def _pad_lanes(x: jax.Array, block: int) -> jax.Array:
    """Zero-pad the last axis to a multiple of `block`."""
    pad = (-x.shape[-1]) % block
    if not pad:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths)


def _unpack_nibbles(b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """int8 byte block -> (low, high) int32 nibble planes in [-8, 7].

    The int32 upcast sign-extends the byte; `& 0xF` then isolates each
    nibble and the `^ 8 - 8` trick re-extends the nibble's own sign bit.
    Works identically on any block shape (elementwise)."""
    bi = b.astype(jnp.int32)
    lo = ((bi & 0xF) ^ 8) - 8
    hi = (((bi >> 4) & 0xF) ^ 8) - 8
    return lo, hi


def _expand_group_scales(s: jax.Array, gs2: int) -> jax.Array:
    """(KT, Gt) per-group scales -> (KT, ROWS, LANE) per-byte multipliers.

    gs2 = group_size // 2 bytes per group; Gt * gs2 == ROWS * LANE, so the
    repeat+reshape is a static in-register broadcast, no gather."""
    return jnp.repeat(s, gs2, axis=1).reshape(s.shape[0], ROWS, LANE)


def _mask_tail_rows(x: jax.Array, kc, *, k: int, tile: int) -> jax.Array:
    """Select-zero rows past K in the ragged tail client chunk.

    Blocks past the array edge read unspecified values (Pallas pads the
    partial block); a select (not a multiply) guarantees even NaN garbage
    cannot poison the f32 accumulators. Trace-time no-op when K divides
    into whole chunks.
    """
    if k % tile == 0:
        return x
    rows = jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0) + kc * tile
    valid = rows < k  # (tile, 1)
    return jnp.where(valid[:, :, None], x, jnp.zeros_like(x))


def _agg_kernel(w_ref, x_ref, y_ref, *, k, tile):
    kc = pl.program_id(1)

    @pl.when(kc == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    w = w_ref[...].astype(jnp.float32)  # (KT, 1)
    x = _mask_tail_rows(x_ref[...].astype(jnp.float32), kc, k=k, tile=tile)
    y_ref[...] += jnp.sum(w[:, :, None] * x, axis=0)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "out_dtype",
                                    "min_kernel_elems"))
def weighted_agg(w: jax.Array, x: jax.Array, *, interpret: bool = True,
                 out_dtype=None, min_kernel_elems=None):
    """y[n] = sum_k w[k] x[k, n]. x: (K, N) any float dtype; f32 accumulate.

    `out_dtype` overrides the result dtype (default: x.dtype) — pass
    jnp.float32 when a bf16 wire buffer must aggregate into the server's
    f32 reference delta without a lossy round-trip through bf16.
    Buffers below `min_kernel_elems` elements (default SMALL_ELEMS; 0
    forces Pallas) compute as one XLA tensordot — the kernel's launch
    cost dominates tiny rounds.
    """
    K, n = x.shape
    if _use_fallback(K, n, min_kernel_elems):
        y = jnp.tensordot(w.reshape(K).astype(jnp.float32),
                          x.astype(jnp.float32), axes=1)
        return y.astype(out_dtype or x.dtype)
    tile, kp = _k_chunks(K)
    rows = _row_block(n)
    x = _pad_lanes(x, rows * LANE)
    m = x.shape[1] // LANE
    x3 = x.reshape(K, m, LANE)
    w2 = _pad_axis0(w.reshape(K).astype(jnp.float32), kp).reshape(kp, 1)

    # grid order: client chunks are the MINOR dimension, so each output
    # tile is revisited across consecutive steps while kc accumulates.
    y = pl.pallas_call(
        functools.partial(_agg_kernel, k=K, tile=tile),
        grid=(m // rows, kp // tile),
        in_specs=[
            pl.BlockSpec((tile, 1), lambda i, kc: (kc, 0)),
            pl.BlockSpec((tile, rows, LANE), lambda i, kc: (kc, i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, LANE), lambda i, kc: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, LANE), jnp.float32),
        interpret=interpret,
    )(w2, x3)
    return y.reshape(-1)[:n].astype(out_dtype or x.dtype)


def _agg_q_kernel(ws_ref, x_ref, y_ref, *, k, tile):
    kc = pl.program_id(1)

    @pl.when(kc == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    ws = ws_ref[...]  # (KT, 1) f32 — weight x per-chunk dequant scale
    x = _mask_tail_rows(
        x_ref[...].astype(jnp.float32) * ws[:, :, None], kc, k=k, tile=tile)
    y_ref[...] += jnp.sum(x, axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def weighted_agg_q(w: jax.Array, values: jax.Array, scales: jax.Array, *,
                   interpret: bool = True):
    """y[n] = sum_k w[k] * scale[k, n // CHUNK] * values[k, n], f32 out.

    values: (K, N) int8 wire buffer; scales: (K, ceil(N / (ROWS*LANE)))
    f32 per-(client, chunk) dequant multipliers (repro.transport layout).
    The weight and the scale fold into ONE multiplier per input tile, so
    fused dequant costs a single extra (K_TILE, 1) VMEM operand per step.
    Lane-tail zero padding needs no scale handling: int8 zeros dequantize
    to zero under any scale.
    """
    K, n = values.shape
    tile, kp = _k_chunks(K)
    x = _pad_lanes(values, ROWS * LANE)
    m = x.shape[1] // LANE
    c = m // ROWS
    assert scales.shape == (K, c), (scales.shape, (K, c))
    x3 = x.reshape(K, m, LANE)
    ws = _pad_axis0(
        w.reshape(K, 1).astype(jnp.float32) * scales.astype(jnp.float32), kp)

    y = pl.pallas_call(
        functools.partial(_agg_q_kernel, k=K, tile=tile),
        grid=(m // ROWS, kp // tile),
        in_specs=[
            pl.BlockSpec((tile, 1), lambda i, kc: (kc, i)),
            pl.BlockSpec((tile, ROWS, LANE), lambda i, kc: (kc, i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, LANE), lambda i, kc: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, LANE), jnp.float32),
        interpret=interpret,
    )(ws, x3)
    return y.reshape(-1)[:n]


def _agg_q4_kernel(ws_ref, x_ref, ye_ref, yo_ref, *, k, tile, gs2):
    kc = pl.program_id(1)

    @pl.when(kc == 0)
    def _init():
        ye_ref[...] = jnp.zeros_like(ye_ref)
        yo_ref[...] = jnp.zeros_like(yo_ref)

    lo, hi = _unpack_nibbles(x_ref[...])
    # (KT, Gt) weight x per-group dequant scales -> per-byte multipliers
    sexp = _expand_group_scales(ws_ref[...], gs2)
    xlo = _mask_tail_rows(lo.astype(jnp.float32) * sexp, kc, k=k, tile=tile)
    xhi = _mask_tail_rows(hi.astype(jnp.float32) * sexp, kc, k=k, tile=tile)
    ye_ref[...] += jnp.sum(xlo, axis=0)
    yo_ref[...] += jnp.sum(xhi, axis=0)


@functools.partial(jax.jit, static_argnames=("n", "group_size", "interpret"))
def weighted_agg_q4(w: jax.Array, values: jax.Array, scales: jax.Array, *,
                    n: int, group_size: int, interpret: bool = True):
    """y[m] = sum_k w[k] * scale[k, m // group_size] * x4[k, m], f32 out.

    values: (K, ceil(n/2)) int8 PACKED wire buffer (two int4 params per
    byte, low nibble first); scales: (K, ceil(n/group_size)) f32 grouped
    dequant multipliers (repro.transport int4 layout); `n` the logical
    element count. The weight folds into the per-group scale on the host
    (one (K_TILE, Gt) VMEM operand per step); nibbles unpack in-register
    and accumulate into separate even/odd f32 planes, interleaved back to
    logical order after the kernel. group_size must be even and divide
    CHUNK = ROWS*LANE (transport.validate_group_size), so a tile covers
    whole groups and a byte never straddles two scales. Zero padding
    bytes dequantize to (0, 0) under any scale.
    """
    K, nb = values.shape
    assert nb == -(-n // 2), (nb, n)
    gs2 = group_size // 2
    tile, kp = _k_chunks(K)
    x = _pad_lanes(values, ROWS * LANE)
    m = x.shape[1] // LANE
    gp = x.shape[1] // gs2  # padded group columns (gs2 | ROWS*LANE | cols)
    gt = (ROWS * LANE) // gs2  # groups per tile
    assert scales.shape[0] == K and scales.shape[1] <= gp, (scales.shape, gp)
    x3 = x.reshape(K, m, LANE)
    # padding scales with 1.0 keeps padded zero bytes at exactly zero
    sp = jnp.pad(scales.astype(jnp.float32),
                 ((0, 0), (0, gp - scales.shape[1])), constant_values=1.0)
    ws = _pad_axis0(w.reshape(K, 1).astype(jnp.float32) * sp, kp)

    ye, yo = pl.pallas_call(
        functools.partial(_agg_q4_kernel, k=K, tile=tile, gs2=gs2),
        grid=(m // ROWS, kp // tile),
        in_specs=[
            pl.BlockSpec((tile, gt), lambda i, kc: (kc, i)),
            pl.BlockSpec((tile, ROWS, LANE), lambda i, kc: (kc, i, 0)),
        ],
        out_specs=(pl.BlockSpec((ROWS, LANE), lambda i, kc: (i, 0)),
                   pl.BlockSpec((ROWS, LANE), lambda i, kc: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((m, LANE), jnp.float32),
                   jax.ShapeDtypeStruct((m, LANE), jnp.float32)),
        interpret=interpret,
    )(ws, x3)
    # interleave the nibble planes back to logical order: y[2j] = ye[j],
    # y[2j+1] = yo[j] — an O(N) shuffle of the f32 OUTPUT, not the wire.
    y = jnp.stack([ye.reshape(-1), yo.reshape(-1)], axis=-1).reshape(-1)
    return y[:n]


def _bdot_kernel(x_ref, g_ref, out_ref, *, k, tile):
    kc = pl.program_id(0)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = _mask_tail_rows(x_ref[...].astype(jnp.float32), kc, k=k, tile=tile)
    g = g_ref[...].astype(jnp.float32)  # (ROWS, LANE)
    out_ref[...] += jnp.sum(x * g[None], axis=(1, 2))[:, None]


@functools.partial(jax.jit,
                   static_argnames=("interpret", "min_kernel_elems"))
def batched_dot(x: jax.Array, g: jax.Array, *, interpret: bool = True,
                min_kernel_elems=None):
    """u[k] = <x[k], g>. x: (K, N), g: (N,). Buffers below
    `min_kernel_elems` elements (default SMALL_ELEMS; 0 forces Pallas)
    compute as one XLA matvec."""
    K, n = x.shape
    if _use_fallback(K, n, min_kernel_elems):
        return x.astype(jnp.float32) @ g.astype(jnp.float32)
    tile, kp = _k_chunks(K)
    rows = _row_block(n)
    x = _pad_lanes(x, rows * LANE)
    g = _pad_lanes(g, rows * LANE)
    m = x.shape[1] // LANE
    x3 = x.reshape(K, m, LANE)
    g2 = g.reshape(m, LANE)

    out = pl.pallas_call(
        functools.partial(_bdot_kernel, k=K, tile=tile),
        grid=(kp // tile, m // rows),
        in_specs=[
            pl.BlockSpec((tile, rows, LANE), lambda kc, i: (kc, i, 0)),
            pl.BlockSpec((rows, LANE), lambda kc, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 1), lambda kc, i: (kc, 0)),
        out_shape=jax.ShapeDtypeStruct((kp, 1), jnp.float32),
        interpret=interpret,
    )(x3, g2)
    return out[:K, 0]
