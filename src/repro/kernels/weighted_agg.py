"""Fused K-way weighted delta aggregation Pallas kernel (TPU target).

FedAdp's global update is y = sum_k w_k * x_k over K client deltas
(Eq. 4/11). A naive implementation is K scaled-add passes (K reads of y);
this kernel streams each (K, ROWS, 128) tile through VMEM once and writes
y once — a single HBM pass over the stacked deltas.

Also provides `batched_dot`: u_k = <x_k, g> for all K clients in one pass
(the per-client angle numerators), sharing the same tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
ROWS = 128  # per-client block: 128*128*4 B = 64 KiB; K<=32 -> <=2 MiB VMEM
# These kernels tile the WHOLE client axis into one VMEM block; past this
# the x tile crowds out double-buffering on a ~16 MiB core. Enforced at
# trace time (K is static) so TPU callers get a ValueError, not an opaque
# Mosaic compile failure.
MAX_K = 32


def check_k(k: int) -> None:
    if k > MAX_K:
        raise ValueError(
            f"K={k} exceeds MAX_K={MAX_K} for whole-K VMEM tiling; shard "
            f"the client axis or use the tree engine")


def _agg_kernel(w_ref, x_ref, y_ref):
    w = w_ref[...].astype(jnp.float32)  # (K, 1)
    x = x_ref[...].astype(jnp.float32)  # (K, ROWS, LANE)
    y_ref[...] = jnp.sum(w[:, :, None] * x, axis=0).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def weighted_agg(w: jax.Array, x: jax.Array, *, interpret: bool = True):
    """y[n] = sum_k w[k] x[k, n]. x: (K, N) any float dtype; f32 accumulate."""
    K, n = x.shape
    check_k(K)
    block = ROWS * LANE
    pad = (-n) % block
    if pad:
        x = jnp.concatenate([x, jnp.zeros((K, pad), x.dtype)], axis=1)
    m = x.shape[1] // LANE
    x3 = x.reshape(K, m, LANE)
    w2 = w.reshape(K, 1).astype(jnp.float32)

    y = pl.pallas_call(
        _agg_kernel,
        grid=(m // ROWS,),
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, ROWS, LANE), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, LANE), x.dtype),
        interpret=interpret,
    )(w2, x3)
    return y.reshape(-1)[:n]


def _bdot_kernel(x_ref, g_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)  # (K, ROWS, LANE)
    g = g_ref[...].astype(jnp.float32)  # (ROWS, LANE)
    out_ref[...] += jnp.sum(x * g[None], axis=(1, 2))[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def batched_dot(x: jax.Array, g: jax.Array, *, interpret: bool = True):
    """u[k] = <x[k], g>. x: (K, N), g: (N,)."""
    K, n = x.shape
    check_k(K)
    block = ROWS * LANE
    pad = (-n) % block
    if pad:
        x = jnp.concatenate([x, jnp.zeros((K, pad), x.dtype)], axis=1)
        g = jnp.concatenate([g, jnp.zeros((pad,), g.dtype)])
    m = x.shape[1] // LANE
    x3 = x.reshape(K, m, LANE)
    g2 = g.reshape(m, LANE)

    out = pl.pallas_call(
        _bdot_kernel,
        grid=(m // ROWS,),
        in_specs=[
            pl.BlockSpec((K, ROWS, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((ROWS, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((K, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((K, 1), jnp.float32),
        interpret=interpret,
    )(x3, g2)
    return out[:, 0]
