"""Fused K-way weighted delta aggregation Pallas kernel (TPU target).

FedAdp's global update is y = sum_k w_k * x_k over K client deltas
(Eq. 4/11). A naive implementation is K scaled-add passes (K reads of y);
this kernel streams (K_TILE, ROWS, 128) tiles through VMEM and writes
each y tile once — a single HBM pass over the stacked deltas.

The client axis is CHUNKED, not whole-K tiled: the grid walks
ceil(K / K_TILE) client chunks per output tile and accumulates partial
sums into the revisited f32 output block (sequential grid steps run in
order on one TPU core, so revisited output blocks act as accumulators —
same pattern as `grad_dot.py`). Any K is served with a bounded VMEM
envelope; the former trace-time MAX_K rejection is gone.

Also provides `batched_dot`: u_k = <x_k, g> for all K clients in one pass
(the per-client angle numerators), sharing the same tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
ROWS = 128  # per-client block: 128*128*4 B = 64 KiB
# Client-axis chunk: 32*128*128*4 B = 2 MiB per x tile — small enough to
# leave VMEM room for double buffering on a ~16 MiB core. K <= K_TILE runs
# as one chunk of size K; larger K is zero-padded to a K_TILE multiple and
# gridded. NOTE: the zero-pad is a jnp.concatenate, i.e. one buffer copy
# whenever K % K_TILE != 0 — keep cohorts at multiples of 32 on the hot
# path (a tail-chunk call to avoid the copy is a ROADMAP next step).
K_TILE = 32


def _k_chunks(k: int) -> tuple[int, int]:
    """(chunk size, padded K) for gridding the client axis."""
    tile = min(k, K_TILE)
    return tile, ((k + tile - 1) // tile) * tile


def _pad_axis0(x: jax.Array, kp: int) -> jax.Array:
    """Zero-pad axis 0 to kp rows (zero clients contribute zero stats)."""
    k = x.shape[0]
    if kp == k:
        return x
    return jnp.concatenate([x, jnp.zeros((kp - k,) + x.shape[1:], x.dtype)])


def _pad_lanes(x: jax.Array, block: int) -> jax.Array:
    """Zero-pad the last axis to a multiple of `block`."""
    pad = (-x.shape[-1]) % block
    if not pad:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths)


def _agg_kernel(w_ref, x_ref, y_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    w = w_ref[...].astype(jnp.float32)  # (KT, 1)
    x = x_ref[...].astype(jnp.float32)  # (KT, ROWS, LANE)
    y_ref[...] += jnp.sum(w[:, :, None] * x, axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def weighted_agg(w: jax.Array, x: jax.Array, *, interpret: bool = True):
    """y[n] = sum_k w[k] x[k, n]. x: (K, N) any float dtype; f32 accumulate."""
    K, n = x.shape
    tile, kp = _k_chunks(K)
    x = _pad_axis0(_pad_lanes(x, ROWS * LANE), kp)
    m = x.shape[1] // LANE
    x3 = x.reshape(kp, m, LANE)
    w2 = _pad_axis0(w.reshape(K).astype(jnp.float32), kp).reshape(kp, 1)

    # grid order: client chunks are the MINOR dimension, so each output
    # tile is revisited across consecutive steps while kc accumulates.
    y = pl.pallas_call(
        _agg_kernel,
        grid=(m // ROWS, kp // tile),
        in_specs=[
            pl.BlockSpec((tile, 1), lambda i, kc: (kc, 0)),
            pl.BlockSpec((tile, ROWS, LANE), lambda i, kc: (kc, i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, LANE), lambda i, kc: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, LANE), jnp.float32),
        interpret=interpret,
    )(w2, x3)
    return y.reshape(-1)[:n].astype(x.dtype)


def _bdot_kernel(x_ref, g_ref, out_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)  # (KT, ROWS, LANE)
    g = g_ref[...].astype(jnp.float32)  # (ROWS, LANE)
    out_ref[...] += jnp.sum(x * g[None], axis=(1, 2))[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def batched_dot(x: jax.Array, g: jax.Array, *, interpret: bool = True):
    """u[k] = <x[k], g>. x: (K, N), g: (N,)."""
    K, n = x.shape
    tile, kp = _k_chunks(K)
    x = _pad_axis0(_pad_lanes(x, ROWS * LANE), kp)
    g = _pad_lanes(g, ROWS * LANE)
    m = x.shape[1] // LANE
    x3 = x.reshape(kp, m, LANE)
    g2 = g.reshape(m, LANE)

    out = pl.pallas_call(
        _bdot_kernel,
        grid=(kp // tile, m // ROWS),
        in_specs=[
            pl.BlockSpec((tile, ROWS, LANE), lambda kc, i: (kc, i, 0)),
            pl.BlockSpec((ROWS, LANE), lambda kc, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 1), lambda kc, i: (kc, 0)),
        out_shape=jax.ShapeDtypeStruct((kp, 1), jnp.float32),
        interpret=interpret,
    )(x3, g2)
    return out[:K, 0]
