"""Synthetic datasets + non-IID federated partitioning.

The container is offline, so the paper's MNIST / FashionMNIST experiments
run on a synthetic 10-class 28x28 image task with matched sizes (600
samples per node, 10 nodes). Class structure: each class is a smooth random
template; a sample is the template under a random sub-pixel shift plus
pixel noise and a random global contrast jitter — hard enough that an MLR /
CNN takes tens of federated rounds, easy enough to reach the paper's target
accuracies. Claims are validated as FedAdp-vs-FedAvg *relative* round
counts on identical data (DESIGN.md §7).

Partitioning follows the paper's protocol: `x-class non-IID` nodes draw all
samples from x (possibly overlapping) classes; IID nodes draw uniformly.
A Dirichlet partitioner is included for general heterogeneity sweeps.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class Dataset(NamedTuple):
    x: np.ndarray  # (N, 28, 28, 1) float32 in [0, 1]
    y: np.ndarray  # (N,) int32


def _templates(rng: np.random.Generator, num_classes: int, side: int) -> np.ndarray:
    """Smooth low-frequency class templates in [0,1]."""
    low = rng.normal(size=(num_classes, 7, 7))
    # bilinear upsample 7x7 -> side x side
    t = np.empty((num_classes, side, side), np.float32)
    xs = np.linspace(0, 6, side)
    x0 = np.clip(xs.astype(int), 0, 5)
    fx = xs - x0
    for c in range(num_classes):
        g = low[c]
        rows = g[x0][:, x0]
        rows_x1 = g[x0 + 1][:, x0]
        rows_y1 = g[x0][:, x0 + 1]
        rows_xy = g[x0 + 1][:, x0 + 1]
        t[c] = (
            rows * (1 - fx)[:, None] * (1 - fx)[None]
            + rows_x1 * fx[:, None] * (1 - fx)[None]
            + rows_y1 * (1 - fx)[:, None] * fx[None]
            + rows_xy * fx[:, None] * fx[None]
        )
    t -= t.min(axis=(1, 2), keepdims=True)
    t /= t.max(axis=(1, 2), keepdims=True) + 1e-8
    return t


def make_image_task(
    seed: int = 0,
    num_train: int = 60000,
    num_test: int = 10000,
    num_classes: int = 10,
    side: int = 28,
    shift: int = 3,
    noise: float = 0.35,
) -> tuple[Dataset, Dataset]:
    """MNIST-shaped synthetic classification task."""
    rng = np.random.default_rng(seed)
    templates = _templates(rng, num_classes, side)

    def gen(n: int, seed2: int) -> Dataset:
        r = np.random.default_rng(seed2)
        y = r.integers(0, num_classes, size=n).astype(np.int32)
        dx = r.integers(-shift, shift + 1, size=n)
        dy = r.integers(-shift, shift + 1, size=n)
        contrast = r.uniform(0.7, 1.3, size=n).astype(np.float32)
        x = np.empty((n, side, side), np.float32)
        for i in range(n):
            img = np.roll(templates[y[i]], (dx[i], dy[i]), axis=(0, 1))
            x[i] = img * contrast[i]
        x += r.normal(scale=noise, size=x.shape).astype(np.float32)
        x = np.clip(x, 0.0, 1.5) / 1.5
        return Dataset(x[..., None], y)

    return gen(num_train, seed + 1), gen(num_test, seed + 2)


# ------------------------------------------------------------ partitions


def partition_iid(rng: np.random.Generator, ds: Dataset, samples: int) -> Dataset:
    idx = rng.choice(len(ds.y), size=samples, replace=False)
    return Dataset(ds.x[idx], ds.y[idx])


def partition_xclass(
    rng: np.random.Generator, ds: Dataset, x_classes: int, samples: int,
    num_classes: int = 10,
) -> Dataset:
    """Paper's x-class non-IID node: all samples from x random classes."""
    classes = rng.choice(num_classes, size=x_classes, replace=False)
    pool = np.flatnonzero(np.isin(ds.y, classes))
    idx = rng.choice(pool, size=samples, replace=len(pool) < samples)
    return Dataset(ds.x[idx], ds.y[idx])


def make_federated(
    train: Dataset,
    node_spec: list,  # e.g. [("iid", None)] * 5 + [("xclass", 1)] * 5
    samples_per_node: int = 600,
    seed: int = 0,
) -> list[Dataset]:
    rng = np.random.default_rng(seed)
    nodes = []
    for kind, x in node_spec:
        if kind == "iid":
            nodes.append(partition_iid(rng, train, samples_per_node))
        elif kind == "xclass":
            nodes.append(partition_xclass(rng, train, x, samples_per_node))
        else:
            raise ValueError(kind)
    return nodes


def dirichlet_partition(
    rng: np.random.Generator, ds: Dataset, num_nodes: int, alpha: float,
    samples_per_node: int, num_classes: int = 10,
) -> list[Dataset]:
    """General heterogeneity: per-node class mixture ~ Dir(alpha)."""
    nodes = []
    by_class = [np.flatnonzero(ds.y == c) for c in range(num_classes)]
    for _ in range(num_nodes):
        mix = rng.dirichlet(np.full(num_classes, alpha))
        counts = rng.multinomial(samples_per_node, mix)
        idx = np.concatenate(
            [rng.choice(by_class[c], size=k, replace=k > len(by_class[c]))
             for c, k in enumerate(counts) if k > 0]
        )
        rng.shuffle(idx)
        nodes.append(Dataset(ds.x[idx], ds.y[idx]))
    return nodes


# -------------------------------------------------------- LM token task


def lm_token_batches(
    seed: int, num_clients: int, batch: int, seq: int, vocab: int,
    zipf_a: float = 1.2, skew: bool = True,
):
    """Synthetic non-IID language-model tokens: every client draws from a
    Zipf distribution over a client-specific permutation of the vocab, so
    client unigram distributions differ (non-IID) while the global mixture
    is smooth."""
    rng = np.random.default_rng(seed)
    ranks = (rng.zipf(zipf_a, size=(num_clients, batch, seq)) - 1) % vocab
    if skew:
        perms = np.stack([rng.permutation(vocab) for _ in range(num_clients)])
        toks = np.take_along_axis(
            perms, ranks.reshape(num_clients, -1), axis=1
        ).reshape(num_clients, batch, seq)
    else:
        toks = ranks
    return toks.astype(np.int32)


def batch_iterator(ds: Dataset, batch_size: int, seed: int):
    """Infinite shuffled mini-batch iterator (per-client local data)."""
    rng = np.random.default_rng(seed)
    n = len(ds.y)
    while True:
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            j = order[i : i + batch_size]
            yield ds.x[j], ds.y[j]
