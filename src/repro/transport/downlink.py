"""Server->client broadcast (downlink) compression.

The uplink layer (`transport.quantize`) compresses the K stacked client
deltas; this module compresses the OTHER half of the round's traffic —
the global model the server broadcasts back to the clients.
`FLConfig(downlink="f32"|"bf16"|"int8")` selects the format; the round
function compresses the raveled (N,) parameter vector once, and every
client trains from the identical dequantized reconstruction, so the
broadcast semantics cannot fork between engines (tree / flat /
flat_sharded all consume the same reconstructed params).

Contract (ROADMAP): downlink="f32" is the reference broadcast — the round
is then bit-identical to a repo without this module. Quantized downlink
reuses the uplink wire formats on a single-row (1, N) buffer (int8: one
f32 scale per kernel-aligned CHUNK), so the roundtrip/error-bound
properties pinned in tests/test_transport_properties.py cover both
directions.

Error feedback (`FLConfig(downlink_error_feedback=True)`) mirrors the
uplink EF-SGD state server-side: the broadcast residual
p - dequantize(quantize(p)) is carried across rounds and added back
before the next compression, so the model the clients see is unbiased
over time even though each individual broadcast is lossy.

Delta encoding (`FLConfig(downlink_delta=True)`): instead of compressing
the full model every round, `delta_compress` quantizes the DIFF between
the current params and the previous round's reconstructed broadcast
(`RoundState.prev_broadcast`, zeros at init so round 0 ships the full
model). The server and every client advance the same reconstruction
prev + dequantize(q), so the stream never drifts; because per-round
model diffs are orders of magnitude smaller than the params, the int8
scales track them far more tightly than a full-model broadcast at the
same byte cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.transport import quantize as quantize_mod
from repro.transport.quantize import DOWNLINKS, dequantize, quantize


def compress(vec: jax.Array, downlink: str) -> quantize_mod.QuantizedDelta:
    """Compress an (N,) f32 parameter vector into the downlink format."""
    if downlink not in DOWNLINKS:
        raise ValueError(f"unknown downlink {downlink!r} "
                         f"(expected one of {DOWNLINKS})")
    return quantize(vec[None, :], downlink)


def decompress(q: quantize_mod.QuantizedDelta) -> jax.Array:
    """(N,) f32 reconstruction — what every client trains from."""
    return dequantize(q)[0]


def broadcast_roundtrip(vec: jax.Array, downlink: str) -> jax.Array:
    """decompress(compress(vec)) — the reconstruction the clients see."""
    if downlink == "f32":
        return vec.astype(jnp.float32)
    return decompress(compress(vec, downlink))


def init_downlink_error_feedback(n: int) -> jax.Array:
    """(N,) f32 server-side broadcast residual carry (EF-SGD, one copy —
    the broadcast is identical for every client)."""
    return jnp.zeros((n,), jnp.float32)


def delta_compress(vec: jax.Array, prev: jax.Array,
                   downlink: str) -> quantize_mod.QuantizedDelta:
    """Compress the (N,) broadcast DIFF `vec - prev` into the downlink
    format (`prev` is the reconstruction the clients already hold)."""
    return compress(vec - prev, downlink)


def delta_decompress(q: quantize_mod.QuantizedDelta,
                     prev: jax.Array) -> jax.Array:
    """(N,) f32 reconstruction the clients advance to: prev + deq(q)."""
    return prev + decompress(q)


def delta_roundtrip(vec: jax.Array, prev: jax.Array,
                    downlink: str) -> jax.Array:
    """delta_decompress(delta_compress(vec)) — one delta-encoded hop."""
    if downlink == "f32":
        return vec.astype(jnp.float32)
    return delta_decompress(delta_compress(vec, prev, downlink), prev)


def init_prev_broadcast(n: int) -> jax.Array:
    """(N,) f32 previous-broadcast carry for delta encoding. Zeros: the
    first delta-encoded broadcast is the diff against nothing, i.e. the
    full model."""
    return jnp.zeros((n,), jnp.float32)
