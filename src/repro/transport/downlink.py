"""Server->client broadcast (downlink) compression.

The uplink layer (`transport.quantize`) compresses the K stacked client
deltas; this module compresses the OTHER half of the round's traffic —
the global model the server broadcasts back to the clients.
`FLConfig(downlink="f32"|"bf16"|"int8")` selects the format; the round
function compresses the raveled (N,) parameter vector once, and every
client trains from the identical dequantized reconstruction, so the
broadcast semantics cannot fork between engines (tree / flat /
flat_sharded all consume the same reconstructed params).

Contract (ROADMAP): downlink="f32" is the reference broadcast — the round
is then bit-identical to a repo without this module. Quantized downlink
reuses the uplink wire formats on a single-row (1, N) buffer (int8: one
f32 scale per kernel-aligned CHUNK), so the roundtrip/error-bound
properties pinned in tests/test_transport_properties.py cover both
directions.

Error feedback (`FLConfig(downlink_error_feedback=True)`) mirrors the
uplink EF-SGD state server-side: the broadcast residual
p - dequantize(quantize(p)) is carried across rounds and added back
before the next compression, so the model the clients see is unbiased
over time even though each individual broadcast is lossy.

Delta encoding (`FLConfig(downlink_delta=True)`): instead of compressing
the full model every round, `delta_compress` quantizes the DIFF between
the current params and the previous round's reconstructed broadcast
(zeros at init so round 0 ships the full model). The server canonical
chain B_v = B_{v-1} + dequantize(q_v) never drifts; because per-round
model diffs are orders of magnitude smaller than the params, the int8
scales track them far more tightly than a full-model broadcast at the
same byte cost.

Per-client state (`BroadcastState`, carried in `fl.RoundState.bcast`):
under partial participation (clients_per_round < num_clients) or
buffered admission, a client does NOT receive every broadcast — its
decode base is the reconstruction of the LAST version it pulled, not
B_{v-1}. The server therefore keeps:

* ``ring``  — (R, N) f32, the delta reconstructions D_j = dequantize(q_j)
  of the last R broadcast versions (slot j holds version v, v % R == j).
* ``head``  — (N,) f32, the current chain reconstruction B_v (what this
  round's pullers train from; plays the old shared prev-broadcast's role
  in the compression math, which is what keeps the full-participation
  path bit-identical).
* ``head_ver`` — () i32, the version of ``head`` (-1 before any
  broadcast).
* ``ver``   — (num_clients,) i32, the last version each client pulled;
  `NEVER_PULLED` (-1) marks clients that must receive a full model.

A client at version w pulling version v replays the ring's deltas
D_{w+1}..D_v onto its held base in version order — f32 additions in the
SAME association order as the server chain, so the decode is bitwise
B_v (`client_decode` is the reference client-side decoder, pinned by
tests/test_downlink_state.py). A client more than R versions behind (or
one that never pulled) cannot replay and receives a full quantized model
instead — catch-up resync (`resync_mask`). The resync payload costs one
full-model unit of `wire_bytes(1, n, downlink)` on the wire; the
simulation hands the resynced client the exact head reconstruction (a
deliberate idealization: re-quantizing the full model would fork that
client's params from the shared broadcast and break the vmapped round's
one-reconstruction contract — the BYTES are accounted, the quantization
noise of the rare resync path is not modeled).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.transport import quantize as quantize_mod
from repro.transport.quantize import DOWNLINKS, dequantize, quantize

# `BroadcastState.ver` sentinel: this client never pulled a broadcast
# (fresh init, or a client added by an elastic-K restore) — it cannot
# delta-decode anything and must receive a full model.
NEVER_PULLED = -1


def compress(vec: jax.Array, downlink: str) -> quantize_mod.QuantizedDelta:
    """Compress an (N,) f32 parameter vector into the downlink format."""
    if downlink not in DOWNLINKS:
        raise ValueError(f"unknown downlink {downlink!r} "
                         f"(expected one of {DOWNLINKS})")
    return quantize(vec[None, :], downlink)


def decompress(q: quantize_mod.QuantizedDelta) -> jax.Array:
    """(N,) f32 reconstruction — what every client trains from."""
    return dequantize(q)[0]


def broadcast_roundtrip(vec: jax.Array, downlink: str) -> jax.Array:
    """decompress(compress(vec)) — the reconstruction the clients see."""
    if downlink == "f32":
        return vec.astype(jnp.float32)
    return decompress(compress(vec, downlink))


def init_downlink_error_feedback(n: int) -> jax.Array:
    """(N,) f32 server-side broadcast residual carry (EF-SGD, one copy —
    the broadcast is identical for every client)."""
    return jnp.zeros((n,), jnp.float32)


def delta_compress(vec: jax.Array, prev: jax.Array,
                   downlink: str) -> quantize_mod.QuantizedDelta:
    """Compress the (N,) broadcast DIFF `vec - prev` into the downlink
    format (`prev` is the reconstruction the clients already hold)."""
    return compress(vec - prev, downlink)


def delta_decompress(q: quantize_mod.QuantizedDelta,
                     prev: jax.Array) -> jax.Array:
    """(N,) f32 reconstruction the clients advance to: prev + deq(q)."""
    return prev + decompress(q)


def delta_roundtrip(vec: jax.Array, prev: jax.Array,
                    downlink: str) -> jax.Array:
    """delta_decompress(delta_compress(vec)) — one delta-encoded hop."""
    if downlink == "f32":
        return vec.astype(jnp.float32)
    return delta_decompress(delta_compress(vec, prev, downlink), prev)


class BroadcastState(NamedTuple):
    """Per-client downlink-delta bookkeeping (see module docstring).

    Carried in `fl.RoundState.bcast` when `FLConfig(downlink_delta=True)`;
    `fl.state_to_tree` round-trips it through the checkpoint codec, with
    `ver` resized (fill = `NEVER_PULLED`) on elastic-K restore.
    """

    ring: jax.Array  # (R, N) f32 — delta recon D_j of the last R versions
    head: jax.Array  # (N,) f32 — current chain reconstruction B_{head_ver}
    head_ver: jax.Array  # () i32 — version of head; -1 before any broadcast
    ver: jax.Array  # (num_clients,) i32 — last version each client pulled


def init_broadcast_state(n: int, num_clients: int,
                         ring: int) -> BroadcastState:
    """Fresh BroadcastState: empty R-deep ring, zero head (the first
    delta-encoded broadcast diffs against nothing, i.e. ships the full
    model), and every client marked `NEVER_PULLED`."""
    if ring < 1:
        raise ValueError(f"downlink ring depth must be >= 1, got {ring}")
    return BroadcastState(
        ring=jnp.zeros((ring, n), jnp.float32),
        head=jnp.zeros((n,), jnp.float32),
        head_ver=jnp.int32(NEVER_PULLED),
        ver=jnp.full((num_clients,), NEVER_PULLED, jnp.int32),
    )


def resync_mask(ver_rows: jax.Array, v, ring: int) -> jax.Array:
    """True where a client at last-pulled version `ver_rows` cannot
    delta-decode broadcast version `v` and needs a full-model resync:
    it never pulled, or it is more than `ring` versions behind (the
    deltas it would replay have been overwritten)."""
    return (ver_rows == NEVER_PULLED) | (v - ver_rows > ring)


def advance_broadcast(bstate: BroadcastState,
                      d_recon: jax.Array) -> BroadcastState:
    """Publish broadcast version v = head_ver + 1: write its delta
    reconstruction `d_recon` into ring slot v % R and advance the chain
    head to B_v = B_{v-1} + D_v. Per-client `ver` rows are updated
    separately by the round function (`ver.at[...].set(v)` for the
    clients that actually pulled / were admitted this round).

    The head add deliberately consumes the row READ BACK from the
    just-updated ring, not `d_recon` itself: the dequantize that
    produces `d_recon` is cheap elementwise work that XLA duplicates
    into every consumer fusion, and inside the head-add fusion LLVM
    contracts the dequantize multiply + add into an FMA (one rounding
    instead of two) — drifting head 1 ulp from what a client replaying
    the STORED ring rows computes. Reading the materialized row forces
    the add to use the exact stored bytes; the read index is spelled
    rem(v + R, R) (== v % R) so the algebraic simplifier cannot
    collapse dynamic-slice(dynamic-update-slice) back to the un-stored
    value. The replay bit-exactness pin in tests/test_downlink_state.py
    guards this against compiler drift."""
    v = bstate.head_ver + 1
    r = jnp.int32(bstate.ring.shape[0])
    ring = jax.lax.dynamic_update_index_in_dim(
        bstate.ring, d_recon, jax.lax.rem(v, r), axis=0)
    d_stored = jax.lax.dynamic_index_in_dim(
        ring, jax.lax.rem(v + r, r), 0, keepdims=False)
    return bstate._replace(
        ring=ring,
        head=bstate.head + d_stored,
        head_ver=v,
    )


def client_decode(bstate: BroadcastState, base: jax.Array,
                  base_ver: int) -> jax.Array:
    """The reference CLIENT-side decoder: replay the ring's delta
    reconstructions base_ver+1 .. head_ver onto the base the client
    actually holds, in version order.

    Because the additions run in the same f32 association order as the
    server chain B_v = B_{v-1} + D_v, the result is bitwise equal to
    `bstate.head` — the regression pin of tests/test_downlink_state.py.
    Host/test helper (python loop over at most R rows); raises if the
    client is outside the ring's reach and needs a full resync.
    """
    v = int(bstate.head_ver)
    w = int(base_ver)
    r = bstate.ring.shape[0]
    if w == NEVER_PULLED or v - w > r:
        raise ValueError(
            f"client at version {w} cannot delta-decode version {v} with "
            f"a {r}-deep ring — it needs a full-model resync")
    out = base
    for j in range(w + 1, v + 1):
        out = out + bstate.ring[j % r]
    return out
