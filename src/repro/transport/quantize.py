"""Client-uplink delta quantization with kernel-aligned scales.

Wire formats over the flat (K, N) client-delta buffer:

* ``f32``  — identity; the reference wire format.
* ``bf16`` — elementwise cast, 2 bytes/param, no side data. Dequant is the
  in-kernel ``astype(f32)`` the round kernels already perform.
* ``int8`` — symmetric per-chunk quantization, 1 byte/param plus one f32
  scale per (client, chunk). q = round(x / s) in [-127, 127] with
  s = absmax(chunk) / 127.
* ``int4`` — symmetric GROUPED quantization, two params per byte (packed
  low/high nibble), plus one f32 scale per (client, group) with
  ``group_size <= CHUNK`` elements per group. q = round(x / s) in [-7, 7]
  with s = absmax(group) / 7. ~8x fewer value bytes than f32.

The chunk is ``CHUNK = ROWS * LANE`` elements — exactly the (ROWS, LANE)
tile each grid step of `kernels.round_stats` / `kernels.weighted_agg`
streams per client, so int8's fused dequant path loads ONE scale per input
tile: scales[k, c] pairs with values[k, c*CHUNK:(c+1)*CHUNK] and chunk c
is grid step i == c of the lane dimension. Zero-padding the lane tail of
a value buffer never needs scale padding: int8 zeros dequantize to zero
under any scale.

int4 breaks that 1:1 scale/tile pairing on purpose: a physical (ROWS,
LANE) byte tile holds TWO logical chunks (2*CHUNK nibbles), and each tile
covers ``2*CHUNK / group_size`` scale groups. The packing is pairwise —
byte j of row k holds logical elements (2j, 2j+1) in its (low, high)
nibbles — so the fused kernels (`round_stats_q4`, `weighted_agg_q4`)
unpack both nibbles in-register and pair them with even/odd views of the
server-side vectors; `group_size` must be even (a byte never straddles a
group) and divide CHUNK (tiles cover whole groups). Nibble coding is
offset-binary-free two's complement in [-7, 7]: 0x8 (== -8) is never
produced, so a zero byte dequantizes to exactly (0, 0) under any scale.

Error feedback (optional, `FLConfig(error_feedback=True)`): the residual
x - dequantize(quantize(x)) is carried per population client and added to
the next round's delta before quantization, so FedAdp's angle statistics
see an unbiased compressed signal over time (EF-SGD; cf. the
resource-constrained uplink motivation in PAPERS.md).

`repro.transport.downlink` reuses these formats for the server->client
broadcast; `round_bytes` reports both directions of the wire.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.weighted_agg import LANE, ROWS, _unpack_nibbles

# One f32 scale per CHUNK wire values per client — 4/CHUNK bytes of side
# data per parameter (~0.02% at the default 16384-element chunk).
CHUNK = ROWS * LANE

# Default int4 scale-group width (FLConfig(group_size=...)): 512 elements
# -> one f32 scale per 256 wire bytes (~1.6% side data), 32 groups per
# kernel tile. Any even divisor of CHUNK in [2, CHUNK] is accepted.
GROUP_SIZE = 512

TRANSPORTS = ("f32", "bf16", "int8", "int4")
# Formats accepted for the server->client broadcast (see downlink.py).
# int4's pairwise packing buys little on a single replicated vector next
# to its extra group-scale traffic; the downlink stops at int8.
DOWNLINKS = ("f32", "bf16", "int8")

_DTYPE_FMT = {jnp.dtype(jnp.float32): "f32",
              jnp.dtype(jnp.bfloat16): "bf16",
              jnp.dtype(jnp.int8): "int8"}


class QuantizedDelta(NamedTuple):
    """Wire-format view of a (K, N) client-delta buffer.

    values: (K, N) in the wire dtype for f32/bf16/int8; for int4 the
      PACKED (K, ceil(N/2)) int8 buffer (two nibbles per byte).
    scales: f32 dequant multipliers — (K, num_chunks(N)) for int8,
      (K, num_groups(N, group_size)) for int4, else None.
    fmt: wire format name; "" infers from the values dtype (legacy int8
      constructions in tests/oracles), which is ambiguous for int4 — the
      int4 quantizer always sets it.
    n: logical element count (int4 only; the packed buffer loses N's
      parity). -1 when values are unpacked.
    group_size: int4 scale-group width; 0 for the per-chunk formats.
    """

    values: jax.Array
    scales: Optional[jax.Array]
    fmt: str = ""
    n: int = -1
    group_size: int = 0

    @property
    def transport(self) -> str:
        return self.fmt or _DTYPE_FMT[jnp.dtype(self.values.dtype)]


def num_chunks(n: int) -> int:
    """Scale columns for an N-wide buffer (== kernel lane-tile grid steps)."""
    return max(1, -(-n // CHUNK))


def num_groups(n: int, group_size: int = GROUP_SIZE) -> int:
    """int4 scale columns for an N-wide buffer (one per group)."""
    return max(1, -(-n // group_size))


def validate_group_size(group_size: int) -> None:
    """int4 group contract: even (a packed byte never straddles a group)
    and a divisor of CHUNK (kernel tiles cover whole groups), in
    [2, CHUNK]. Raises ValueError otherwise."""
    if (
        not isinstance(group_size, int)
        or not 2 <= group_size <= CHUNK
        or group_size % 2
        or CHUNK % group_size
    ):
        raise ValueError(
            f"int4 group_size must be an even divisor of CHUNK={CHUNK} in "
            f"[2, {CHUNK}]; got {group_size!r}")


def _pad_to_chunks(flat: jax.Array) -> jax.Array:
    pad = (-flat.shape[1]) % CHUNK
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat


def _quantize_int8(flat: jax.Array) -> QuantizedDelta:
    k, n = flat.shape
    c = num_chunks(n)
    xp = _pad_to_chunks(flat.astype(jnp.float32)).reshape(k, c, CHUNK)
    absmax = jnp.max(jnp.abs(xp), axis=2)
    # all-zero chunks get scale 1 (quantize to zeros) instead of 0/0
    scales = jnp.where(absmax > 0.0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xp / scales[:, :, None]), -127.0, 127.0)
    values = q.astype(jnp.int8).reshape(k, c * CHUNK)[:, :n]
    return QuantizedDelta(values, scales, "int8")


def pack_int4(q: jax.Array) -> jax.Array:
    """Pack an even-width (K, 2M) int array in [-7, 7] to (K, M) int8:
    byte j = (q[2j] & 0xF) | (q[2j+1] << 4)."""
    k, n2 = q.shape
    assert n2 % 2 == 0, n2
    qi = q.astype(jnp.int32)
    lo, hi = qi[:, 0::2], qi[:, 1::2]
    b = (lo & 0xF) | ((hi & 0xF) << 4)  # [0, 255]
    return jnp.where(b > 127, b - 256, b).astype(jnp.int8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """(K, M) int8 -> (K, 2M) int32 nibbles in [-8, 7], interleaved back
    to logical order (low nibble first).

    Shares the nibble decode with the fused kernels so the wire coding
    cannot drift between the reference dequantizer and the in-register
    path; the decode itself is pinned independently by the roundtrip
    property tests (quantize is separate code)."""
    lo, hi = _unpack_nibbles(packed)
    k, m = packed.shape
    return jnp.stack([lo, hi], axis=-1).reshape(k, 2 * m)


def _quantize_int4(flat: jax.Array, group_size: int) -> QuantizedDelta:
    validate_group_size(group_size)
    k, n = flat.shape
    g = num_groups(n, group_size)
    total = g * group_size
    xp = jnp.pad(flat.astype(jnp.float32), ((0, 0), (0, total - n)))
    xg = xp.reshape(k, g, group_size)
    absmax = jnp.max(jnp.abs(xg), axis=2)
    scales = jnp.where(absmax > 0.0, absmax / 7.0, 1.0)
    q = jnp.clip(jnp.round(xg / scales[:, :, None]), -7.0, 7.0)
    # group_size is even, so the even-width slice never splits a byte;
    # keep the minimal even width covering n.
    ne = n + (n % 2)
    values = pack_int4(q.reshape(k, total)[:, :ne])
    return QuantizedDelta(values, scales, "int4", n, group_size)


def quantize(flat: jax.Array, transport: str, *,
             group_size: int = GROUP_SIZE) -> QuantizedDelta:
    """Compress a (K, N) f32 delta buffer into the wire format.

    `group_size` applies to int4 only (grouped scales); int8 keeps one
    scale per kernel-aligned CHUNK."""
    if transport not in TRANSPORTS:
        raise ValueError(f"unknown transport {transport!r} "
                         f"(expected one of {TRANSPORTS})")
    if transport == "f32":
        return QuantizedDelta(flat.astype(jnp.float32), None, "f32")
    if transport == "bf16":
        return QuantizedDelta(flat.astype(jnp.bfloat16), None, "bf16")
    if transport == "int4":
        return _quantize_int4(flat, group_size)
    return _quantize_int8(flat)


def dequantize(q: QuantizedDelta) -> jax.Array:
    """(K, N) f32 reconstruction — the reference the fused kernels match."""
    if q.scales is None:
        return q.values.astype(jnp.float32)
    if q.transport == "int4":
        if q.n < 0:
            raise ValueError(
                "int4 QuantizedDelta needs its logical width (n); construct "
                "it through transport.quantize")
        k = q.values.shape[0]
        g, gs = q.scales.shape[1], q.group_size
        x = unpack_int4(q.values).astype(jnp.float32)
        pad = g * gs - x.shape[1]
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad)))
        x = (x.reshape(k, g, gs) * q.scales[:, :, None]).reshape(k, g * gs)
        return x[:, :q.n]
    k, n = q.values.shape
    c = q.scales.shape[1]
    xp = _pad_to_chunks(q.values.astype(jnp.float32)).reshape(k, c, CHUNK)
    return (xp * q.scales[:, :, None]).reshape(k, c * CHUNK)[:, :n]


def roundtrip(flat: jax.Array, transport: str, *,
              group_size: int = GROUP_SIZE) -> jax.Array:
    """dequantize(quantize(x)) — the tree engine's dequantize-then-reference
    view of the wire (it never reads quantized buffers directly)."""
    if transport == "f32":
        return flat.astype(jnp.float32)
    return dequantize(quantize(flat, transport, group_size=group_size))


def wire_bytes(k: int, n: int, transport: str, *,
               group_size: int = GROUP_SIZE) -> int:
    """Uplink bytes for K clients x N params (values + scale side data)."""
    if transport == "f32":
        return k * n * 4
    if transport == "bf16":
        return k * n * 2
    if transport == "int8":
        return k * n * 1 + k * num_chunks(n) * 4
    if transport == "int4":
        return k * -(-n // 2) + k * num_groups(n, group_size) * 4
    raise ValueError(f"unknown transport {transport!r}")


def round_bytes(k: int, n: int, transport: str, downlink: str = "f32", *,
                group_size: int = GROUP_SIZE,
                delta_payloads: int | None = None,
                full_clients: int | None = None) -> dict:
    """Both directions of one round's wire traffic, in bytes.

    up:    K client uplinks of the delta buffer in `transport`.
    down:  unicast accounting (multicast fabrics pay less) of the
           server->client broadcasts in `downlink`. Default: K clients
           each receiving one N-param payload — which is exact for a
           full broadcast, and the degenerate case of the delta-encoded
           downlink under full participation (every client is exactly
           one version behind, so each pulls one delta payload).
    total: up + down.

    Under `downlink_delta` with partial participation the per-client
    payload counts vary by staleness: pass `delta_payloads` (the summed
    number of single-version delta payloads served this round — a
    client b versions behind replays b of them) and `full_clients` (the
    number of clients resynced with a full model) to get the actual
    split; the dict then also carries "down_delta" and "down_full"
    (down == down_delta + down_full), matching the round's
    `tel/bytes_down_delta` / `tel/bytes_down_full` metrics. Both
    directions price one payload at `wire_bytes(1, n, downlink)` — a
    delta payload ships the same quantized (N,) buffer as a full one;
    the saving is needing ONE per missed version instead of K full
    models every round.
    """
    if downlink not in DOWNLINKS:
        raise ValueError(f"unknown downlink {downlink!r} "
                         f"(expected one of {DOWNLINKS})")
    if (delta_payloads is None) != (full_clients is None):
        raise ValueError("delta_payloads and full_clients must be "
                         "passed together (the delta/full split of one "
                         "round's downlink)")
    up = wire_bytes(k, n, transport, group_size=group_size)
    unit = wire_bytes(1, n, downlink)
    if delta_payloads is None:
        down = k * unit
        return {"up": up, "down": down, "total": up + down}
    down_delta = delta_payloads * unit
    down_full = full_clients * unit
    down = down_delta + down_full
    return {"up": up, "down": down, "down_delta": down_delta,
            "down_full": down_full, "total": up + down}


def init_error_feedback(num_clients: int, n: int) -> jax.Array:
    """(num_clients, N) f32 residual carry, one row per population slot."""
    return jnp.zeros((num_clients, n), jnp.float32)
