"""Client-uplink delta quantization with kernel-aligned per-chunk scales.

Wire formats over the flat (K, N) client-delta buffer:

* ``f32``  — identity; the reference wire format.
* ``bf16`` — elementwise cast, 2 bytes/param, no side data. Dequant is the
  in-kernel ``astype(f32)`` the round kernels already perform.
* ``int8`` — symmetric per-chunk quantization, 1 byte/param plus one f32
  scale per (client, chunk). q = round(x / s) in [-127, 127] with
  s = absmax(chunk) / 127.

The chunk is ``CHUNK = ROWS * LANE`` elements — exactly the (ROWS, LANE)
tile each grid step of `kernels.round_stats` / `kernels.weighted_agg`
streams per client, so the fused dequant path loads ONE scale per input
tile: scales[k, c] pairs with values[k, c*CHUNK:(c+1)*CHUNK] and chunk c
is grid step i == c of the lane dimension. Zero-padding the lane tail of
a value buffer never needs scale padding: int8 zeros dequantize to zero
under any scale.

Error feedback (optional, `FLConfig(error_feedback=True)`): the residual
x - dequantize(quantize(x)) is carried per population client and added to
the next round's delta before quantization, so FedAdp's angle statistics
see an unbiased compressed signal over time (EF-SGD; cf. the
resource-constrained uplink motivation in PAPERS.md).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.weighted_agg import LANE, ROWS

# One f32 scale per CHUNK wire values per client — 4/CHUNK bytes of side
# data per parameter (~0.02% at the default 16384-element chunk).
CHUNK = ROWS * LANE

TRANSPORTS = ("f32", "bf16", "int8")


class QuantizedDelta(NamedTuple):
    """Wire-format view of a (K, N) client-delta buffer.

    values: (K, N) in the wire dtype (f32 / bf16 / int8).
    scales: (K, num_chunks(N)) f32 for int8, else None — per-(client,
      chunk) dequant multipliers aligned to the kernels' lane tiling.
    """

    values: jax.Array
    scales: Optional[jax.Array]

    @property
    def transport(self) -> str:
        return {jnp.dtype(jnp.float32): "f32",
                jnp.dtype(jnp.bfloat16): "bf16",
                jnp.dtype(jnp.int8): "int8"}[jnp.dtype(self.values.dtype)]


def num_chunks(n: int) -> int:
    """Scale columns for an N-wide buffer (== kernel lane-tile grid steps)."""
    return max(1, -(-n // CHUNK))


def _pad_to_chunks(flat: jax.Array) -> jax.Array:
    pad = (-flat.shape[1]) % CHUNK
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat


def quantize(flat: jax.Array, transport: str) -> QuantizedDelta:
    """Compress a (K, N) f32 delta buffer into the wire format."""
    if transport not in TRANSPORTS:
        raise ValueError(f"unknown transport {transport!r} "
                         f"(expected one of {TRANSPORTS})")
    if transport == "f32":
        return QuantizedDelta(flat.astype(jnp.float32), None)
    if transport == "bf16":
        return QuantizedDelta(flat.astype(jnp.bfloat16), None)
    k, n = flat.shape
    c = num_chunks(n)
    xp = _pad_to_chunks(flat.astype(jnp.float32)).reshape(k, c, CHUNK)
    absmax = jnp.max(jnp.abs(xp), axis=2)
    # all-zero chunks get scale 1 (quantize to zeros) instead of 0/0
    scales = jnp.where(absmax > 0.0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xp / scales[:, :, None]), -127.0, 127.0)
    values = q.astype(jnp.int8).reshape(k, c * CHUNK)[:, :n]
    return QuantizedDelta(values, scales)


def dequantize(q: QuantizedDelta) -> jax.Array:
    """(K, N) f32 reconstruction — the reference the fused kernels match."""
    if q.scales is None:
        return q.values.astype(jnp.float32)
    k, n = q.values.shape
    c = q.scales.shape[1]
    xp = _pad_to_chunks(q.values.astype(jnp.float32)).reshape(k, c, CHUNK)
    return (xp * q.scales[:, :, None]).reshape(k, c * CHUNK)[:, :n]


def roundtrip(flat: jax.Array, transport: str) -> jax.Array:
    """dequantize(quantize(x)) — the tree engine's dequantize-then-reference
    view of the wire (it never reads quantized buffers directly)."""
    if transport == "f32":
        return flat.astype(jnp.float32)
    return dequantize(quantize(flat, transport))


def wire_bytes(k: int, n: int, transport: str) -> int:
    """Uplink bytes for K clients x N params (values + scale side data)."""
    if transport == "f32":
        return k * n * 4
    if transport == "bf16":
        return k * n * 2
    if transport == "int8":
        return k * n * 1 + k * num_chunks(n) * 4
    raise ValueError(f"unknown transport {transport!r}")


def init_error_feedback(num_clients: int, n: int) -> jax.Array:
    """(num_clients, N) f32 residual carry, one row per population slot."""
    return jnp.zeros((num_clients, n), jnp.float32)
