"""Delta transport: the wire format between client uplink and server.

`quantize` compresses a client-stacked (K, N) f32 delta buffer into the
configured wire dtype (f32 passthrough, bf16 cast, or int8 with per-chunk
f32 scales aligned to the round kernels' tiling); the fused Pallas kernels
(`kernels.round_stats.round_stats_q`, `kernels.weighted_agg.weighted_agg_q`)
read the wire buffer directly and dequantize in-register, so the server's
stats + aggregation stay a single HBM pass over ~4x fewer bytes.

Contract (ROADMAP): transport="f32" is the reference wire format; the tree
engine never reads quantized buffers directly — it dequantizes back to the
stacked tree and runs the per-leaf reference reductions.
"""
from repro.transport.quantize import (  # noqa: F401
    CHUNK,
    TRANSPORTS,
    QuantizedDelta,
    dequantize,
    init_error_feedback,
    num_chunks,
    quantize,
    roundtrip,
    wire_bytes,
)
