"""Delta transport: the bidirectional wire between clients and server.

Uplink — `quantize` compresses a client-stacked (K, N) f32 delta buffer
into the configured wire dtype (f32 passthrough, bf16 cast, int8 with
per-chunk f32 scales aligned to the round kernels' tiling, or int4 packed
two-params-per-byte with grouped scales); the fused Pallas kernels
(`kernels.round_stats.round_stats_q{,4}`,
`kernels.weighted_agg.weighted_agg_q{,4}`) read the wire buffer directly
and dequantize in-register, so the server's stats + aggregation stay a
single HBM pass over ~4x (int8) / ~8x (int4) fewer bytes.

Downlink — `downlink.compress` applies the same formats to the (N,)
global model the server broadcasts back (f32 / bf16 / int8), with
optional server-side error feedback; `downlink.delta_compress` ships
the quantized model DIFF against the broadcast chain head instead
(`FLConfig(downlink_delta=True)`). Per-client delta state — the head,
an R-deep ring of delta reconstructions, and each client's last-pulled
version — is a `downlink.BroadcastState` carried in
`fl.RoundState.bcast`, so partially-participating clients decode
against the base they actually hold (or take a full-model resync when
more than R versions behind); `round_bytes` reports both directions,
including the delta/full downlink split.

Contract (ROADMAP): transport="f32" is the reference wire format and
downlink="f32" the reference broadcast; the tree engine never reads
quantized buffers directly — it dequantizes back to the stacked tree and
runs the per-leaf reference reductions.
"""
from repro.transport import downlink  # noqa: F401
from repro.transport.quantize import (  # noqa: F401
    CHUNK,
    DOWNLINKS,
    GROUP_SIZE,
    TRANSPORTS,
    QuantizedDelta,
    dequantize,
    init_error_feedback,
    num_chunks,
    num_groups,
    pack_int4,
    quantize,
    round_bytes,
    roundtrip,
    unpack_int4,
    validate_group_size,
    wire_bytes,
)
