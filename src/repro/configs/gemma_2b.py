"""gemma-2b — GeGLU, head_dim=256, MQA, tied 256k vocab [arXiv:2403.08295]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    arch_type="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16384,
    vocab_size=256000,
    head_dim=256,
    mlp="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    source="arXiv:2403.08295 (Gemma-2B: 18L d2048 8H MQA hd256)",
)
