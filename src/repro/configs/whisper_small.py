"""whisper-small — enc-dec audio backbone; mel+conv frontend is a stub that
supplies precomputed frame embeddings [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    arch_type="audio",
    num_layers=12,  # decoder layers; encoder_layers below
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    mlp="gelu",
    norm="layernorm",
    rope_style="none",  # sinusoidal additive positions
    tie_embeddings=True,
    encoder_layers=12,
    encoder_len=1500,
    source="arXiv:2212.04356 (Whisper small: 12+12L d768 12H)",
)
