"""deepseek-v2-236b — MLA (kv_lora=512, q_lora=1536) + MoE 160 routed
top-6 with 2 shared experts [arXiv:2405.04434].

Deviation from the released model: every layer is MoE (the release uses a
dense FFN in layer 1); the assigned config specifies uniform 160e top-6.
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=1536,
    vocab_size=102400,
    moe_pattern="all",
    tie_embeddings=False,
    moe=MoEConfig(num_experts=160, top_k=6, num_shared=2, d_ff_expert=1536),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    source="arXiv:2405.04434 (DeepSeek-V2: 60L d5120 128H, MLA 512, 160e top6)",
)
