"""starcoder2-15b — GQA (kv=4), RoPE, GPT-style LayerNorm+GeLU FFN
[arXiv:2402.19173]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    arch_type="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    mlp="gelu",
    norm="layernorm",
    rope_theta=100000.0,
    tie_embeddings=False,
    source="arXiv:2402.19173 (StarCoder2-15B: 40L d6144 48H kv4)",
)
