"""granite-20b — code model, llama-style stack with MQA (kv=1)
[arXiv:2405.04324]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    arch_type="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp="gelu",  # non-gated FFN: gated-3-matrix would overshoot 20B -> 28B
    norm="rmsnorm",
    tie_embeddings=False,
    source="arXiv:2405.04324 (Granite-20B code: 52L d6144 48H MQA)",
)
