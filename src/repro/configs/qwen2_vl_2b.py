"""qwen2-vl-2b — VLM language backbone with M-RoPE; the ViT vision encoder
is a stub that supplies precomputed patch embeddings [arXiv:2409.12191]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    arch_type="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    rope_style="mrope",
    mrope_sections=(16, 24, 24),  # of head_dim/2 = 64
    rope_theta=1000000.0,
    tie_embeddings=True,
    vision_prefix=256,  # stub: 256 precomputed patch embeddings per sample
    source="arXiv:2409.12191 (Qwen2-VL-2B: 28L d1536 12H kv2, M-RoPE)",
)
