"""deepseek-v2-lite-16b — MLA (kv_lora=512, no q LoRA) + MoE 64 routed
top-6 with 2 shared experts [arXiv:2405.04434]."""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    moe_pattern="all",
    tie_embeddings=False,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_ff_expert=1408),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    source="arXiv:2405.04434 (DeepSeek-V2-Lite: 27L d2048 16H, MLA 512, 64e top6)",
)
