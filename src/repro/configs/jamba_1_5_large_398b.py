"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave with MoE
(16 experts, top-2) on every other layer [arXiv:2403.19887].

Block group of 8 layers: attention at position 4 (as in the Jamba paper's
block figure), Mamba elsewhere; MoE FFN on odd positions, dense FFN on even.
"""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    moe_pattern="odd",
    tie_embeddings=False,
    moe=MoEConfig(num_experts=16, top_k=2, num_shared=0, d_ff_expert=24576),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, dt_rank=512),
    source="arXiv:2403.19887 + Jamba-1.5 (72L d8192 64H kv8, 16e top2, 1:7)",
)
