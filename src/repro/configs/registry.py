"""Architecture registry: --arch <id> -> ModelConfig."""
from __future__ import annotations

from repro.configs import (
    deepseek_v2_236b,
    deepseek_v2_lite_16b,
    gemma_2b,
    granite_20b,
    jamba_1_5_large_398b,
    minitron_4b,
    qwen2_vl_2b,
    rwkv6_3b,
    starcoder2_15b,
    whisper_small,
)
from repro.models.config import ModelConfig, reduced

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        rwkv6_3b.CONFIG,
        starcoder2_15b.CONFIG,
        qwen2_vl_2b.CONFIG,
        deepseek_v2_236b.CONFIG,
        whisper_small.CONFIG,
        minitron_4b.CONFIG,
        granite_20b.CONFIG,
        deepseek_v2_lite_16b.CONFIG,
        jamba_1_5_large_398b.CONFIG,
        gemma_2b.CONFIG,
    ]
}


def get(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return reduced(ARCHS[name[: -len("-smoke")]])
    return ARCHS[name]


def smoke(name: str, **overrides) -> ModelConfig:
    return reduced(ARCHS[name], **overrides)
