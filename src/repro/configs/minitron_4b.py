"""minitron-4b — pruned Nemotron: GQA kv=8, squared-ReLU FFN, LayerNorm
[arXiv:2407.14679]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    arch_type="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    mlp="relu_sq",
    norm="layernorm",
    tie_embeddings=False,
    source="arXiv:2407.14679 (Minitron-4B: 32L d3072 24H kv8, pruned Nemotron)",
)
