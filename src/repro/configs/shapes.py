"""The four assigned input shapes and ShapeDtypeStruct input specs.

`input_specs(cfg, shape)` returns (step_kind, spec_dict) where step_kind is
"train" | "prefill" | "decode" and the specs are jax.ShapeDtypeStruct
stand-ins (no device allocation) suitable for jit(...).lower(**specs).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import mamba, rwkv6
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# sliding window applied to *pure full-attention* archs for long_500k only
# (DESIGN.md §5); SSM/hybrid/MLA archs run their native sub-quadratic path.
LONG_CONTEXT_WINDOW = 8192


def needs_swa_for_long(cfg: ModelConfig) -> bool:
    return cfg.mla is None and cfg.block_pattern == ("attn",)


def config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    if shape.name == "long_500k" and needs_swa_for_long(cfg):
        return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def token_batch_specs(cfg: ModelConfig, B: int, T: int) -> dict:
    """Specs for a full-sequence batch (train / prefill)."""
    specs = {"tokens": _sds((B, T), jnp.int32)}
    if cfg.vision_prefix:
        specs["vision_embeds"] = _sds((B, cfg.vision_prefix, cfg.d_model), cfg.jdtype)
        specs["positions"] = _sds((3, B, T + cfg.vision_prefix), jnp.int32)
    if cfg.encoder_layers:
        specs["enc_embeds"] = _sds((B, cfg.encoder_len, cfg.d_model), cfg.jdtype)
    return specs


def cache_specs(cfg: ModelConfig, B: int, max_len: int) -> dict:
    """ShapeDtypeStruct tree matching transformer.init_cache (no alloc)."""
    from repro.models import transformer

    return jax.eval_shape(lambda: transformer.init_cache(cfg, B, max_len))


def decode_specs(cfg: ModelConfig, B: int, seq_len: int) -> dict:
    return {
        "token": _sds((B, 1), jnp.int32),
        "cache": cache_specs(cfg, B, seq_len),
        "pos": _sds((), jnp.int32),
    }
