"""rwkv6-3b — Finch, attention-free data-dependent-decay linear attention
[arXiv:2404.05892]."""
from repro.models.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,  # d_model / rwkv head_dim (64)
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    block_pattern=("rwkv",),
    rope_style="none",
    tie_embeddings=False,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, chunk_len=16),
    source="arXiv:2404.05892 (RWKV-6 'Finch', 3B: 32L d2560)",
)
