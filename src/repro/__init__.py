"""Public API facade for the FedAdp reproduction.

The curated, stable import surface — everything a training script needs
without reaching into `repro.core.*`:

    import repro

    cfg = repro.FLConfig(num_clients=10, clients_per_round=10,
                         local_steps=0, aggregation="buffered",
                         buffer_m=7).validate()
    server = repro.FedServer("mlr", cfg, nodes, test, batch_size=32)
    hist = server.run(300, target_acc=0.85, mode="scanned")

`__all__` is pinned by tests/test_api.py; grow it deliberately. The
deeper modules (`repro.core`, `repro.kernels`, `repro.transport`, ...)
remain importable for tests and internals, but scripts/examples/
benchmarks go through this facade.
"""
from repro.core.fl import (  # noqa: F401
    FLConfig,
    RoundState,
    init_round_state,
    make_round_fn,
    state_from_tree,
    state_to_tree,
)
from repro.core.server import (  # noqa: F401
    FedServer,
    History,
    fixed_arrival_schedule,
)
from repro import telemetry  # noqa: F401
from repro.telemetry.manifest import run_manifest  # noqa: F401
from repro.telemetry.sinks import (  # noqa: F401
    CSVSink,
    JSONLSink,
    MemorySink,
)
from repro.telemetry.spans import SpanTimer  # noqa: F401

__all__ = [
    "CSVSink",
    "FLConfig",
    "FedServer",
    "History",
    "JSONLSink",
    "MemorySink",
    "RoundState",
    "SpanTimer",
    "fixed_arrival_schedule",
    "init_round_state",
    "make_round_fn",
    "run_manifest",
    "state_from_tree",
    "state_to_tree",
    "telemetry",
]
