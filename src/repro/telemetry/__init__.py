"""Round-level telemetry: per-node contribution traces, phase timing
spans, and pluggable sinks.

FedAdp's mechanism is an observable quantity — the angle between each
node's delta and the global delta, mapped through the Gompertz function
into an aggregation weight. This package makes a run's internals
inspectable WITHOUT touching the compiled path when it is off:

* **In-scan metrics** — `FLConfig(telemetry="node")` makes every
  engine's `round_fn` metrics dict carry the per-node internals
  (``tel/*`` keys: node attribution, cohort mask, weight entropy, wire
  bytes; buffered mode adds staleness ages, landed mask, occupancy).
  With the default ``telemetry=None`` the metrics dict — and the jaxpr
  — are byte-identical to a build without this package.
* **Sinks** (`telemetry.sinks`) — the `TelemetrySink` protocol with
  JSONL (manifest-headed, durable), CSV, and in-memory implementations;
  `emit_round_block` adapts stacked scan metrics to schema events at
  block boundaries.
* **Spans** (`telemetry.spans`) — `SpanTimer`, block_until_ready-bounded
  host phase timing with optional `jax.profiler` trace annotations.
* **Schema** (`telemetry.schema`) — the versioned JSONL event contract,
  including the in-scan eval sentinel `EVAL_SENTINEL`.
* **Manifest** (`telemetry.manifest`) — run provenance (commit, jax
  version, device topology, config hash), shared with ``BENCH_*.json``.
* **Report** (`telemetry.report`) — the `scripts/flstat.py` logic:
  summaries, rounds-to-target from the stream alone, weight-sum checks.
"""
from repro.telemetry import manifest, report, schema, sinks, spans  # noqa: F401
from repro.telemetry.manifest import run_manifest  # noqa: F401
from repro.telemetry.schema import EVAL_SENTINEL, SCHEMA_VERSION  # noqa: F401
from repro.telemetry.sinks import (  # noqa: F401
    CSVSink,
    JSONLSink,
    MemorySink,
    TelemetrySink,
    emit_manifest,
    emit_round_block,
    emit_summary,
    load_events,
)
from repro.telemetry.spans import SpanTimer  # noqa: F401
