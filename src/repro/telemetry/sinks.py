"""Pluggable telemetry sinks plus the metrics -> events adapter.

A sink is anything with ``emit(event: dict)`` and ``close()``
(`TelemetrySink` protocol). Three implementations ship:

* `JSONLSink(path)` — the durable format: one JSON object per line,
  manifest first (`scripts/flstat.py` reads it back).
* `CSVSink(path)` — flat per-node rows (round scalars repeated per row)
  for spreadsheet-shaped consumers.
* `MemorySink()` — in-process list, the test/bench surface.

`emit_round_block` is the one adapter from the engines' stacked metrics
dicts (host numpy, one leading round axis after `lax.scan` /
`driver.run_rounds`) to schema events — both the stepwise per-round path
and the scanned block path go through it, which is what makes
scanned-vs-stepwise telemetry parity a test rather than a hope. It
consumes the base metrics every round already carries (loss, theta,
theta_smoothed, weights, ...) plus the ``tel/*`` keys the engines add
when `FLConfig(telemetry="node")` is set, and it masks the in-scan eval
sentinel (`schema.EVAL_SENTINEL`) to None so accuracy traces never
ingest non-eval rounds as data.
"""
from __future__ import annotations

import csv
import json
import os
from typing import Optional, Protocol, runtime_checkable

import numpy as np

from repro.telemetry import manifest as manifest_mod
from repro.telemetry import schema


@runtime_checkable
class TelemetrySink(Protocol):
    def emit(self, event: dict) -> None: ...

    def close(self) -> None: ...


class MemorySink:
    """Keeps every event in `self.events` (tests, benches)."""

    def __init__(self):
        self.events: list = []
        self.closed = False

    def emit(self, event: dict) -> None:
        self.events.append(event)

    def close(self) -> None:
        self.closed = True

    def of_type(self, kind: str) -> list:
        return [e for e in self.events if e.get("event") == kind]


class JSONLSink:
    """One JSON object per line; the file opens lazily on first emit."""

    def __init__(self, path: str):
        self.path = path
        self._fh = None

    def emit(self, event: dict) -> None:
        if self._fh is None:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._fh = open(self.path, "w")
        self._fh.write(json.dumps(event, default=_json_default) + "\n")

    def flush(self) -> None:
        if self._fh is not None:
            self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class CSVSink:
    """Flat per-node rows (plus accuracy/loss repeated from the round).

    Spans/manifest/summary don't fit a rectangular file and are skipped;
    use JSONL for the full stream.
    """

    COLUMNS = ("round", "node", "theta", "theta_smoothed", "weight",
               "age", "landed", "loss", "accuracy")

    def __init__(self, path: str):
        self.path = path
        self._fh = None
        self._writer = None
        self._round_ctx: dict = {}

    def emit(self, event: dict) -> None:
        kind = event.get("event")
        if kind == "round":
            self._round_ctx = {"loss": event.get("loss"),
                               "accuracy": event.get("accuracy")}
            return
        if kind != "node":
            return
        if self._writer is None:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._fh = open(self.path, "w", newline="")
            self._writer = csv.DictWriter(self._fh, self.COLUMNS,
                                          extrasaction="ignore")
            self._writer.writeheader()
        self._writer.writerow({**self._round_ctx, **event})

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


def _json_default(x):
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, np.ndarray):
        return x.tolist()
    raise TypeError(f"not JSON-serializable: {type(x)}")


def load_events(path: str) -> list:
    """Read a JSONL telemetry stream back into a list of event dicts."""
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


def emit_manifest(sink: TelemetrySink, cfg=None,
                  extra: Optional[dict] = None) -> None:
    """Write the run manifest as the stream's first event (idempotent —
    a sink shared by warmup + run still gets exactly one manifest)."""
    if getattr(sink, "_manifest_done", False):
        return
    sink.emit(manifest_mod.run_manifest(cfg, extra))
    sink._manifest_done = True


def emit_summary(sink: TelemetrySink, *, rounds: int,
                 final_accuracy: Optional[float] = None,
                 rounds_to_target: Optional[int] = None,
                 target_acc: Optional[float] = None) -> None:
    ev = {"event": "summary", "rounds": int(rounds)}
    if final_accuracy is not None:
        ev["final_accuracy"] = float(final_accuracy)
    if rounds_to_target is not None:
        ev["rounds_to_target"] = int(rounds_to_target)
    if target_acc is not None:
        ev["target_acc"] = float(target_acc)
    sink.emit(ev)


# metric key -> round-event field for scalars that ride along verbatim.
_ROUND_SCALARS = (
    ("loss", "loss"), ("lr", "lr"), ("divergence", "divergence"),
    ("tel/weight_entropy", "weight_entropy"),
    ("tel/bytes_up", "bytes_up"), ("tel/bytes_down", "bytes_down"),
    ("tel/bytes_down_delta", "bytes_down_delta"),
    ("tel/bytes_down_full", "bytes_down_full"),
    ("flushed", "flushed"), ("buffer_landed", "buffer_landed"),
    ("tel/occupancy", "occupancy"), ("staleness", "staleness"),
)
_INT_FIELDS = {"flushed", "buffer_landed", "occupancy", "bytes_up",
               "bytes_down", "bytes_down_delta", "bytes_down_full"}


def emit_round_block(sink: TelemetrySink, metrics: dict, start_round: int,
                     every: int = 1) -> int:
    """Emit round + per-node events for a block of rounds.

    `metrics` is a host-side dict as `driver.run_rounds` returns it
    (every value stacked over a leading round axis) or as a single
    stepwise `FedServer.step` returns it (scalars / (K,) rows — then
    treated as a 1-round block). Rounds are ABSOLUTE: the block covers
    rounds ``start_round+1 .. start_round+R`` (post-round indices, the
    same convention as ``rounds_to_target``). `every` subsamples: only
    rounds with (absolute round) % every == 0 emit (1 = all).

    Per-node events need the engines' ``tel/nodes`` attribution row
    (`FLConfig(telemetry="node")`); without it only round events emit.
    Returns the number of rounds emitted.
    """
    ms = {k: np.asarray(v) for k, v in metrics.items()}
    if ms["loss"].ndim == 0:  # single stepwise round -> 1-round block
        ms = {k: v[None] for k, v in ms.items()}
    r_total = ms["loss"].shape[0]
    nodes = ms.get("tel/nodes")
    emitted = 0
    for r in range(r_total):
        rnd = start_round + r + 1
        if every > 1 and rnd % every:
            continue
        ev = {"event": "round", "round": rnd}
        for key, field in _ROUND_SCALARS:
            if key in ms:
                v = ms[key][r]
                ev[field] = int(v) if field in _INT_FIELDS else float(v)
        if "accuracy" in ms:
            ev["accuracy"] = schema.mask_accuracy(ms["accuracy"][r])
        sink.emit(ev)
        emitted += 1
        if nodes is None:
            continue
        ages = ms.get("tel/ages")
        landed = ms.get("tel/landed")
        for j, node in enumerate(np.asarray(nodes[r]).tolist()):
            nev = {
                "event": "node", "round": rnd, "node": int(node),
                "theta": float(ms["theta"][r][j]),
                "theta_smoothed": float(ms["theta_smoothed"][r][j]),
                "weight": float(ms["weights"][r][j]),
            }
            if ages is not None:
                nev["age"] = int(ages[r][j])
            if landed is not None:
                nev["landed"] = bool(landed[r][j])
            sink.emit(nev)
    return emitted
