"""Telemetry stream post-processing: the logic behind `scripts/flstat.py`.

`summarize(events)` turns a validated JSONL stream back into the run's
headline numbers — rounds run, rounds-to-target (recomputed from the
accuracy trace alone, so a stream is sufficient evidence for a Table-I
claim), per-node angle/weight trajectories, wire bytes, and per-span
wall-clock percentiles. `check_weight_sums` asserts the FedAdp softmax
invariant (weights of a round sum to 1) over the node rows — the CI
telemetry-smoke job runs it on every stream it produces.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.telemetry import schema
from repro.telemetry.sinks import load_events  # noqa: F401  (re-export)


def _percentile(sorted_vals, q: float) -> float:
    """Linear-interpolation percentile (numpy's default method).

    The previous `round()` on the fractional rank used banker's
    rounding, so half-valued ranks picked the lower sample for even
    positions and the upper for odd ones — p50 of [1, 2, 3, 4] came out
    2, not 2.5. Interpolating between the bracketing samples makes the
    estimate continuous in q and order-consistent across span lists.
    """
    if not sorted_vals:
        return math.nan
    pos = q * (len(sorted_vals) - 1)
    pos = min(len(sorted_vals) - 1, max(0.0, pos))
    lo = math.floor(pos)
    hi = math.ceil(pos)
    if lo == hi:
        return sorted_vals[lo]
    frac = pos - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


def rounds_to_target(events: list, target: float) -> Optional[int]:
    """First round whose (real, non-sentinel) accuracy >= target."""
    best = None
    for ev in events:
        if ev.get("event") != "round":
            continue
        acc = ev.get("accuracy")
        if acc is None or not schema.is_real_accuracy(acc):
            continue
        if acc >= target and (best is None or ev["round"] < best):
            best = ev["round"]
    return best


def node_trajectories(events: list) -> dict:
    """node id -> {"rounds": [...], "theta": [...], "theta_smoothed":
    [...], "weight": [...]} in round order."""
    out: dict = {}
    for ev in events:
        if ev.get("event") != "node":
            continue
        t = out.setdefault(ev["node"], {"rounds": [], "theta": [],
                                        "theta_smoothed": [], "weight": []})
        t["rounds"].append(ev["round"])
        t["theta"].append(ev["theta"])
        t["theta_smoothed"].append(ev["theta_smoothed"])
        t["weight"].append(ev["weight"])
    return out


def check_weight_sums(events: list, tol: float = 1e-5) -> int:
    """Assert sum_i w_i == 1 (within `tol`) for every round with node
    rows; buffered non-flush ticks (round.flushed == 0) are exempt —
    their weights are the zeros of a skipped aggregation. Returns the
    number of rounds checked; raises ValueError naming the first bad
    round."""
    flushed = {ev["round"]: ev.get("flushed")
               for ev in events if ev.get("event") == "round"}
    sums: dict = {}
    for ev in events:
        if ev.get("event") == "node":
            sums[ev["round"]] = sums.get(ev["round"], 0.0) + ev["weight"]
    checked = 0
    for rnd in sorted(sums):
        if flushed.get(rnd) == 0:
            continue
        if abs(sums[rnd] - 1.0) > tol:
            raise ValueError(
                f"round {rnd}: node weights sum to {sums[rnd]:.8f}, "
                f"expected 1 within {tol}")
        checked += 1
    return checked


def summarize(events: list, target: float = 0.85) -> dict:
    """Headline numbers of a telemetry stream (see module docstring)."""
    schema.validate_events(events)
    man = next((e for e in events if e["event"] == "manifest"), None)
    rounds = [e for e in events if e["event"] == "round"]
    accs = [(e["round"], e["accuracy"]) for e in rounds
            if e.get("accuracy") is not None]
    spans: dict = {}
    for ev in events:
        if ev["event"] == "span":
            spans.setdefault(ev["name"], []).append(ev["dur_s"])
    span_stats = {}
    for name, ds in spans.items():
        ds = sorted(ds)
        span_stats[name] = {
            "count": len(ds), "total_s": sum(ds),
            "p50_s": _percentile(ds, 0.50), "p90_s": _percentile(ds, 0.90),
            "p99_s": _percentile(ds, 0.99),
        }
    traj = node_trajectories(events)
    return {
        "manifest": man,
        "rounds": len(rounds),
        "first_round": min((e["round"] for e in rounds), default=None),
        "last_round": max((e["round"] for e in rounds), default=None),
        "evals": len(accs),
        "final_accuracy": accs[-1][1] if accs else None,
        "target_acc": target,
        "rounds_to_target": rounds_to_target(events, target),
        "nodes": sorted(traj),
        "node_trajectories": traj,
        "bytes_up": sum(e.get("bytes_up", 0) for e in rounds),
        "bytes_down": sum(e.get("bytes_down", 0) for e in rounds),
        "spans": span_stats,
    }


def format_summary(s: dict, per_node: bool = False) -> str:
    """Human-readable rendering of `summarize`'s dict."""
    man = s.get("manifest") or {}
    lines = []
    cfg_hash = man.get("config_hash")
    lines.append(
        f"run: commit={man.get('git_commit') or '?'} "
        f"jax={man.get('jax_version') or '?'} "
        f"devices={man.get('device_count')}x{man.get('device_kind') or '?'} "
        f"config={cfg_hash[:12] if cfg_hash else '?'}")
    rtt = s["rounds_to_target"]
    acc = s["final_accuracy"]
    lines.append(
        f"rounds {s['first_round']}..{s['last_round']} ({s['rounds']} run, "
        f"{s['evals']} evals) final_acc="
        f"{'n/a' if acc is None else f'{acc:.4f}'} "
        f"rounds_to_{s['target_acc']:.0%}={rtt if rtt is not None else '>'}")
    if s["bytes_up"] or s["bytes_down"]:
        lines.append(f"wire: up={int(s['bytes_up'])}B "
                     f"down={int(s['bytes_down'])}B")
    for name, st in sorted(s["spans"].items()):
        lines.append(
            f"span {name}: n={st['count']} total={st['total_s']:.3f}s "
            f"p50={st['p50_s']*1e3:.1f}ms p90={st['p90_s']*1e3:.1f}ms "
            f"p99={st['p99_s']*1e3:.1f}ms")
    if per_node:
        for node in s["nodes"]:
            t = s["node_trajectories"][node]
            n = len(t["weight"])
            lines.append(
                f"node {node}: rounds={n} "
                f"theta_sm_last={t['theta_smoothed'][-1]:.4f} "
                f"w_mean={sum(t['weight'])/n:.4f} "
                f"w_last={t['weight'][-1]:.4f}")
    return "\n".join(lines)


def oneline(s: dict) -> str:
    """One-line summary for launcher exit messages."""
    rtt = s["rounds_to_target"]
    acc = s["final_accuracy"]
    return (f"telemetry: {s['rounds']} rounds, {s['evals']} evals, "
            f"{len(s['nodes'])} nodes, final_acc="
            f"{'n/a' if acc is None else f'{acc:.4f}'}, "
            f"rounds_to_{s['target_acc']:.0%}="
            f"{rtt if rtt is not None else 'not reached'}")
