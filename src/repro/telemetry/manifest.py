"""Run-manifest: the provenance header every telemetry stream and bench
artifact carries.

`run_manifest()` collects what is needed to compare two artifacts across
commits and machines: the telemetry schema version, an ISO-8601 UTC
timestamp, the git commit of the working tree (best-effort), the jax
version, the device topology (backend, count, kind), and — when a config
is given — its JSON-safe dict plus a stable sha256 hash, so "same
config?" is one string comparison. `benchmarks/run.py` embeds the same
manifest in every ``BENCH_*.json`` and the JSONL sinks write it as the
stream's first event.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import subprocess
from datetime import datetime, timezone
from typing import Any, Optional

from repro.telemetry import schema


def git_commit(cwd: Optional[str] = None) -> Optional[str]:
    """Current commit hash (with a ``-dirty`` suffix when the tree has
    uncommitted changes), or None outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=cwd, capture_output=True,
            text=True, timeout=10)
        if out.returncode != 0:
            return None
        commit = out.stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], cwd=cwd, capture_output=True,
            text=True, timeout=10)
        if dirty.returncode == 0 and dirty.stdout.strip():
            commit += "-dirty"
        return commit
    except (OSError, subprocess.SubprocessError):
        return None


def config_dict(cfg: Any) -> Any:
    """A JSON-safe view of a config (dataclasses become dicts)."""
    if cfg is None:
        return None
    if dataclasses.is_dataclass(cfg) and not isinstance(cfg, type):
        cfg = dataclasses.asdict(cfg)
    return cfg


def config_hash(cfg: Any) -> Optional[str]:
    """Stable sha256 of the config's sorted-key JSON (None for None)."""
    d = config_dict(cfg)
    if d is None:
        return None
    blob = json.dumps(d, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()


def run_manifest(cfg: Any = None, extra: Optional[dict] = None) -> dict:
    """The ``manifest`` telemetry event (see `telemetry.schema`).

    Imports jax lazily so readers (flstat on a laptop) can build
    manifests of their own without a jax install.
    """
    try:
        import jax

        devices = jax.devices()
        jax_info = {
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "device_kind": devices[0].device_kind if devices else None,
        }
    except Exception:  # no jax / no backend — still a valid manifest
        jax_info = {"jax_version": "unavailable", "backend": "none",
                    "device_count": 0, "device_kind": None}
    ev = {
        "event": "manifest",
        "schema": schema.SCHEMA_VERSION,
        "timestamp": datetime.now(timezone.utc).isoformat(),
        "git_commit": git_commit(os.path.dirname(os.path.abspath(__file__))),
        **jax_info,
        "config": config_dict(cfg),
        "config_hash": config_hash(cfg),
    }
    if extra:
        ev["extra"] = dict(extra)
    return ev
