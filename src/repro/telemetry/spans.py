"""Host-level phase timing spans, block_until_ready-bounded.

The compiled round is one dispatch — the host cannot see broadcast /
local-train / uplink / aggregate as separate wall-clock phases inside
it (use `profile=True`, which wraps every span in a
`jax.profiler.TraceAnnotation`, and the profiler's own HLO-level
annotations for that). What the host CAN bound exactly is each
dispatch-granular phase of a run — stepwise rounds, scan blocks, eval,
host sync/`device_get`, checkpoint writes, sink flushes — and that is
precisely the granularity the buffered-vs-sync wall-clock question
needs: one span per server round/tick either way.

    spans = SpanTimer(sink)
    with spans.span("scan_block", round=done):
        state, ms = run_block(state, ...)
        spans.sync(ms)            # block_until_ready: bound the span

Every span emits a ``span`` event (`telemetry.schema`) and accumulates
into `totals` / `counts` for the end-of-run percentile summary
(`scripts/flstat.py` reports p50/p90/p99 per span name).
"""
from __future__ import annotations

import contextlib
import time
from typing import Optional

from repro.telemetry.sinks import TelemetrySink


class SpanTimer:
    """Named wall-clock spans -> sink events + in-process aggregates."""

    def __init__(self, sink: Optional[TelemetrySink] = None,
                 profile: bool = False):
        self.sink = sink
        self.profile = profile
        self.totals: dict = {}
        self.counts: dict = {}
        self.durations: dict = {}

    @staticmethod
    def sync(x) -> None:
        """Block until `x`'s arrays are ready — call as the LAST line
        inside a span so the span bounds device work, not dispatch."""
        import jax

        jax.block_until_ready(x)

    @contextlib.contextmanager
    def span(self, name: str, round: Optional[int] = None):
        ctx = contextlib.nullcontext()
        if self.profile:
            import jax.profiler

            ctx = jax.profiler.TraceAnnotation(name)
        t0 = time.perf_counter()
        with ctx:
            yield
        dur = time.perf_counter() - t0
        self.totals[name] = self.totals.get(name, 0.0) + dur
        self.counts[name] = self.counts.get(name, 0) + 1
        self.durations.setdefault(name, []).append(dur)
        if self.sink is not None:
            ev = {"event": "span", "name": name, "dur_s": dur, "t0": t0}
            if round is not None:
                ev["round"] = int(round)
            self.sink.emit(ev)
