"""Versioned JSONL event schema for the round-level telemetry layer.

A telemetry stream is a sequence of JSON objects (one per line). Every
event carries an ``event`` discriminator; the first event of a stream is
the run ``manifest`` (provenance: config, commit, devices, timestamp —
see `telemetry.manifest`). The schema is VERSIONED: the manifest pins
``schema`` = `SCHEMA_VERSION`, readers (`scripts/flstat.py`,
`telemetry.report`) accept only versions they know, and any new
RoundState-adjacent metric must land here (required/optional field
tables below) plus tests before it ships — that contract lives in
ROADMAP.md.

Event types:

``manifest``  run provenance header (one per stream, first line)
``round``     one aggregation round/tick: scalar round metrics
``node``      one (round, node) row: the FedAdp internals — the
              instantaneous angle theta, the Eq. 9 smoothed angle, and
              the Gompertz-softmax aggregation weight; buffered mode
              adds the report's staleness ``age`` and ``landed`` flag
``span``      a host-side timing span (block_until_ready-bounded)
``summary``   end-of-run rollup (rounds run, target round, final acc)

This module is import-light on purpose (no jax, no repro.core): the
compiled path never sees it, and readers can load it anywhere.
"""
from __future__ import annotations

from typing import Iterable

SCHEMA_VERSION = 1

# The in-scan eval sentinel: `driver.make_step_fn` fills
# metrics["accuracy"] with this exact value on rounds where the
# lax.cond-gated eval did NOT run ((r+1) % eval_every != 0, or
# eval_every == 0). It is written as an exact float32 constant, so
# readers may compare with `==`; `is_real_accuracy` / `mask_accuracy`
# are the one true masking helpers — sinks and flstat must never ingest
# sentinel rounds as data.
EVAL_SENTINEL = -1.0

EVENT_TYPES = ("manifest", "round", "node", "span", "summary")

# required / optional field names (beyond "event") per event type.
REQUIRED_FIELDS = {
    "manifest": ("schema", "timestamp", "jax_version", "backend",
                 "device_count"),
    "round": ("round", "loss", "lr", "divergence"),
    "node": ("round", "node", "theta", "theta_smoothed", "weight"),
    "span": ("name", "dur_s"),
    "summary": ("rounds",),
}
OPTIONAL_FIELDS = {
    "manifest": ("git_commit", "device_kind", "config", "config_hash",
                 "argv", "extra"),
    "round": ("accuracy", "weight_entropy", "bytes_up", "bytes_down",
              "bytes_down_delta", "bytes_down_full",
              "flushed", "buffer_landed", "occupancy", "staleness"),
    "node": ("age", "landed"),
    "span": ("round", "t0"),
    "summary": ("final_accuracy", "rounds_to_target", "target_acc",
                "total_bytes_up", "total_bytes_down"),
}

_NUMERIC = (int, float)


def is_real_accuracy(acc) -> bool:
    """True iff `acc` is a measured accuracy, not the eval sentinel."""
    return acc is not None and float(acc) != EVAL_SENTINEL


def mask_accuracy(acc):
    """Measured accuracy as float, or None for sentinel rounds."""
    return float(acc) if is_real_accuracy(acc) else None


def validate_event(ev: dict) -> None:
    """Raise ValueError naming the problem if `ev` violates the schema."""
    if not isinstance(ev, dict):
        raise ValueError(f"telemetry event must be a dict, got {type(ev)}")
    kind = ev.get("event")
    if kind not in EVENT_TYPES:
        raise ValueError(
            f"unknown telemetry event type {kind!r} (expected one of "
            f"{EVENT_TYPES})")
    missing = [f for f in REQUIRED_FIELDS[kind] if ev.get(f) is None]
    if missing:
        raise ValueError(f"{kind} event lacks required fields {missing}")
    if kind == "manifest" and ev["schema"] != SCHEMA_VERSION:
        raise ValueError(
            f"telemetry schema version {ev['schema']} != supported "
            f"{SCHEMA_VERSION}")
    if kind == "round":
        for f in ("loss", "lr", "divergence"):
            if not isinstance(ev[f], _NUMERIC):
                raise ValueError(f"round.{f} must be numeric, got {ev[f]!r}")
        acc = ev.get("accuracy")
        if acc is not None and float(acc) == EVAL_SENTINEL:
            raise ValueError(
                "round.accuracy carries the eval sentinel — sinks must "
                "mask non-eval rounds to null (schema.mask_accuracy)")
    if kind == "node":
        if not isinstance(ev["node"], int):
            raise ValueError(f"node.node must be int, got {ev['node']!r}")
        for f in ("theta", "theta_smoothed", "weight"):
            if not isinstance(ev[f], _NUMERIC):
                raise ValueError(f"node.{f} must be numeric, got {ev[f]!r}")
    if kind == "span" and not isinstance(ev["dur_s"], _NUMERIC):
        raise ValueError(f"span.dur_s must be numeric, got {ev['dur_s']!r}")


def validate_events(events: Iterable[dict]) -> dict:
    """Validate a whole stream; returns per-type counts.

    Enforces stream-level invariants too: the first event must be the
    manifest, and there must be exactly one manifest.
    """
    counts = {k: 0 for k in EVENT_TYPES}
    for i, ev in enumerate(events):
        validate_event(ev)
        kind = ev["event"]
        if i == 0 and kind != "manifest":
            raise ValueError(
                f"first telemetry event must be the manifest, got {kind!r}")
        if kind == "manifest" and counts["manifest"]:
            raise ValueError("telemetry stream has more than one manifest")
        counts[kind] += 1
    if counts["manifest"] == 0 and sum(counts.values()):
        raise ValueError("telemetry stream has no manifest")
    return counts
