"""Model configuration for the unified architecture zoo.

One dataclass covers every assigned architecture family:
dense (GQA/MQA), MoE (incl. DeepSeek-V2 MLA), SSM (RWKV6), hybrid
(Jamba Mamba+attention interleave), enc-dec audio backbone (Whisper),
and VLM language backbone (Qwen2-VL with M-RoPE).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int  # routed experts
    top_k: int
    num_shared: int = 0  # shared (always-on) experts
    d_ff_expert: int = 0  # per-expert hidden size
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # combine-scatter accumulation dtype: f32 (default) or bfloat16 — a
    # token sums at most top_k + shared expert outputs, so bf16 combine is
    # benign and halves the dominant dispatch-stream HBM traffic (§Perf).
    combine_dtype: str = "float32"
    # apply MoE every `every` layers within a block pattern (hybrid use)
    # — for pure-MoE models all layers are MoE.


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 => dense q projection
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective SSM (used by the Jamba hybrid)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 => ceil(d_model/16)
    # steps executed inside one scan iteration (unrolled): the (B, d_inner,
    # d_state) carry round-trips HBM once per scan ITERATION, so unrolling
    # divides state traffic by this factor (§Perf memory-term optimization).
    scan_unroll: int = 1
    # dtype for the (T, B, d_inner) x_c/dt streams fed to the selective
    # scan; recurrence math stays f32 in-body. bfloat16 halves the dominant
    # residual HBM traffic after unrolling (§Perf memory-term optimization).
    stream_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 (Finch) data-dependent-decay linear attention."""

    head_dim: int = 64
    decay_lora: int = 64  # LoRA rank for the data-dependent decay
    chunk_len: int = 16  # chunked-scan block length (see rwkv6.py numerics)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads
    # block pattern: one entry per layer-position inside a repeating group.
    # ("attn",) => plain transformer; Jamba uses 1 attn : 7 mamba.
    block_pattern: Tuple[str, ...] = ("attn",)
    # which positions inside the pattern use MoE for their FFN ("all", "odd",
    # "none") — Jamba puts MoE on every other layer.
    moe_pattern: str = "none"
    mlp: str = "swiglu"  # swiglu | geglu | gelu | relu_sq
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 10000.0
    rope_style: str = "rope"  # rope | mrope | none
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # of head_dim/2
    sliding_window: int = 0  # 0 => full attention
    q_chunk: int = 0  # >0: query-blocked attention (memory-term opt, §Perf)
    # >0: compute the unembed+cross-entropy over T in chunks of this many
    # tokens, so the (B, T, V) logits tensor is never materialized
    # (memory-term opt for 150k-256k vocabularies, §Perf).
    loss_chunk: int = 0
    # apply in-model activation sharding constraints (batch stays on the
    # data axes through attention) — collective-term opt, §Perf.
    act_constrain: bool = False
    # attention backend: "xla" (einsum) or "flash" (Pallas fused kernel —
    # TPU target; on CPU it executes in interpret mode, so keep it for
    # small smoke shapes only). Train/prefill full-sequence path only.
    attention_impl: str = "xla"
    tie_embeddings: bool = True
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # enc-dec (whisper): number of encoder layers; frontend is a stub that
    # consumes precomputed frame embeddings.
    encoder_layers: int = 0
    encoder_len: int = 1500
    # vlm: number of stub patch-embedding positions prepended to the text.
    vision_prefix: int = 0
    dtype: str = "bfloat16"
    # citation for the assigned config
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def num_pattern_groups(self) -> int:
        assert self.num_layers % len(self.block_pattern) == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern length {len(self.block_pattern)}"
        )
        return self.num_layers // len(self.block_pattern)

    def layer_kinds(self) -> Tuple[Tuple[str, bool], ...]:
        """(kind, is_moe) per position within one repeating group."""
        out = []
        for i, kind in enumerate(self.block_pattern):
            if self.moe is None or self.moe_pattern == "none":
                is_moe = False
            elif self.moe_pattern == "all":
                is_moe = True
            elif self.moe_pattern == "odd":
                is_moe = i % 2 == 1
            else:
                raise ValueError(self.moe_pattern)
            out.append((kind, is_moe))
        return tuple(out)

    def param_count(self) -> int:
        """Total parameter count (analytic; matches init_params)."""
        from repro.models import transformer  # lazy, avoids cycle

        return transformer.count_params(self)

    def active_param_count(self) -> int:
        from repro.models import transformer

        return transformer.count_params(self, active_only=True)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (<=2 groups,
    d_model<=512, <=4 experts)."""
    pattern = cfg.block_pattern
    small = dict(
        num_layers=2 * len(pattern),
        d_model=256,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads > 1 else 1,
        d_ff=512,
        head_dim=64 if cfg.head_dim else 0,
        vocab_size=512,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_len=32 if cfg.encoder_layers else cfg.encoder_len,
        vision_prefix=8 if cfg.vision_prefix else 0,
        dtype="float32",
    )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            num_shared=min(cfg.moe.num_shared, 1),
            d_ff_expert=128,
        )
    if cfg.mla is not None:
        small["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=64, q_lora_rank=0, rope_head_dim=32,
            nope_head_dim=32, v_head_dim=32,
        )
    if cfg.ssm is not None:
        small["ssm"] = dataclasses.replace(cfg.ssm, d_state=8)
    if cfg.rwkv is not None:
        small["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=32, decay_lora=16, chunk_len=8)
        small["num_heads"] = small["d_model"] // 32
        small["num_kv_heads"] = small["num_heads"]
    if cfg.rope_style == "mrope":
        small["mrope_sections"] = (8, 12, 12)  # of reduced head_dim/2 = 32
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)
