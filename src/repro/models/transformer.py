"""Unified architecture assembly for the whole model zoo.

A model is a repeating group of `len(cfg.block_pattern)` blocks, scanned
`cfg.num_pattern_groups` times with stacked parameters (bounded HLO size —
a 72-layer Jamba lowers as one 9-iteration scan over an 8-block group).

Block kinds: "attn" (GQA/MQA or MLA; + cross-attention for enc-dec),
"mamba", "rwkv". Every non-rwkv block has an FFN slot (dense MLP or MoE
according to cfg.moe_pattern); rwkv blocks embed their own channel-mix.

Three entry points:
  forward(..., mode="train")    -> (logits, aux_loss)
  forward(..., mode="prefill")  -> (logits, aux_loss, cache)
  decode_step(...)              -> (logits, cache)   # one token
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention, layers, mamba, mla, moe, rwkv6
from repro.models.config import ModelConfig

PyTree = Any


# =================================================================== init


def _ffn_init(key, cfg, is_moe: bool) -> dict:
    if is_moe:
        return moe.moe_init(key, cfg)
    return layers.mlp_init(key, cfg.d_model, cfg.d_ff, cfg.mlp, cfg.jdtype)


def _block_init(key, cfg, kind: str, is_moe: bool, cross: bool) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict = {"norm1": layers.norm_init(d, cfg.norm, cfg.jdtype)}
    if kind == "attn":
        p["mixer"] = mla.mla_init(k1, cfg) if cfg.mla else attention.attn_init(k1, cfg)
    elif kind == "mamba":
        p["mixer"] = mamba.mamba_init(k1, cfg)
    elif kind == "rwkv":
        p["mixer"] = rwkv6.rwkv_init(k1, cfg)
        p["norm2"] = layers.norm_init(d, cfg.norm, cfg.jdtype)
        return p  # rwkv block embeds its channel-mix; no separate FFN slot
    else:
        raise ValueError(kind)
    if cross:
        p["norm_cross"] = layers.norm_init(d, cfg.norm, cfg.jdtype)
        p["cross"] = attention.cross_attn_init(k4, cfg)
    p["norm2"] = layers.norm_init(d, cfg.norm, cfg.jdtype)
    p["ffn"] = _ffn_init(k2, cfg, is_moe)
    return p


def _stack_init(key, cfg, *, cross: bool, num_groups: int) -> dict:
    """Stacked block params: {"p{i}": leaves with leading G axis}."""
    kinds = cfg.layer_kinds()
    out = {}
    for i, (kind, is_moe) in enumerate(kinds):
        keys = jax.random.split(jax.random.fold_in(key, i), num_groups)
        out[f"p{i}"] = jax.vmap(
            lambda k: _block_init(k, cfg, kind, is_moe, cross)
        )(keys)
    return out


def init_params(key, cfg: ModelConfig) -> PyTree:
    ke, kb, kh, kenc = jax.random.split(key, 4)
    params = {
        "embed": layers.embed_init(ke, cfg.vocab_size, cfg.d_model, cfg.jdtype),
        "blocks": _stack_init(
            kb, cfg, cross=cfg.encoder_layers > 0, num_groups=cfg.num_pattern_groups
        ),
        "final_norm": layers.norm_init(cfg.d_model, cfg.norm, cfg.jdtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(kh, cfg.d_model, cfg.vocab_size, cfg.jdtype)
    if cfg.encoder_layers:
        # encoder is a plain full-attention stack (one group per layer pair)
        enc_groups = cfg.encoder_layers
        params["encoder"] = {
            "blocks": _stack_init(kenc, cfg, cross=False, num_groups=enc_groups),
            "final_norm": layers.norm_init(cfg.d_model, cfg.norm, cfg.jdtype),
        }
    return params


# ============================================================ positions


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    """Additive sinusoidal embedding (whisper-style decoder positions)."""
    half = d // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _rope_for(cfg, batch, B, T, offset=0):
    """cos/sin for the configured rope style; None for rope_style='none'."""
    hd = cfg.mla.rope_head_dim if cfg.mla else cfg.hd
    if cfg.rope_style == "none":
        return None, None
    if cfg.rope_style == "mrope":
        pos = batch.get("positions")
        if pos is None:
            base = jnp.arange(T)[None].repeat(B, 0) + offset
            pos = jnp.broadcast_to(base[None], (3, B, T))
        return layers.mrope_cos_sin(pos, hd, cfg.rope_theta, cfg.mrope_sections)
    pos = jnp.arange(T)[None].repeat(B, 0) + offset
    return layers.rope_cos_sin(pos, hd, cfg.rope_theta)


# =============================================================== blocks


def _mixer(bp, cfg, kind, x, cos, sin, mode, cache, pos, window):
    """Dispatch one mixer. Returns (y, new_cache_or_None)."""
    if kind == "attn":
        if cfg.mla:
            if mode == "decode":
                return mla.mla_decode(bp["mixer"], cfg, x, cache, pos, cos, sin)
            return mla.mla_forward(
                bp["mixer"], cfg, x, cos, sin,
                return_cache=(mode == "prefill"), max_len=cache,
            )
        if mode == "decode":
            return attention.attn_decode(
                bp["mixer"], cfg, x, cache, pos, cos, sin, window=window
            )
        return attention.attn_forward(
            bp["mixer"], cfg, x, cos, sin, causal=True, window=window,
            return_cache=(mode == "prefill"), max_len=cache if mode == "prefill" else 0,
        )
    if kind == "mamba":
        st = cache if mode == "decode" else None
        y, ns = mamba.mamba_forward(bp["mixer"], cfg, x, st)
        return y, (ns if mode in ("prefill", "decode") else None)
    raise ValueError(kind)


def _block(bp, cfg, kind, is_moe, x, ctx, cache, mode):
    """One block. Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.norm_apply(bp["norm1"], x)
    if kind == "rwkv":
        st = cache if mode == "decode" else None
        if mode == "decode":
            y, tm_state = rwkv6.time_mix_decode(bp["mixer"], cfg, h, st)
        else:
            y, tm_state = rwkv6.time_mix(bp["mixer"], cfg, h, st)
        x = x + y
        # rwkv: channel-mix lives inside the block (own token-shift state)
        h2 = layers.norm_apply(bp["norm2"], x)
        cm_last = cache["cm_last"] if mode == "decode" else None
        y2, new_cm = rwkv6.channel_mix(bp["mixer"], h2, cm_last)
        x = x + y2
        new_cache = None
        if mode in ("prefill", "decode"):
            new_cache = dict(tm_state, cm_last=new_cm)
        return x, new_cache, aux

    y, new_cache = _mixer(bp, cfg, kind, h, ctx["cos"], ctx["sin"], mode,
                          cache, ctx["pos"], ctx["window"])
    x = x + y
    if "cross" in bp:
        hc = layers.norm_apply(bp["norm_cross"], x)
        if mode == "decode":
            kv = {"k": cache["cross_k"], "v": cache["cross_v"]}
        else:
            kv = attention.cross_attn_kv(bp["cross"], cfg, ctx["enc"])
        x = x + attention.cross_attn_apply(bp["cross"], cfg, hc, kv)
        if mode == "prefill":
            new_cache = dict(new_cache or {}, cross_k=kv["k"], cross_v=kv["v"])
        elif mode == "decode":
            new_cache = dict(new_cache or {}, cross_k=cache["cross_k"],
                             cross_v=cache["cross_v"])
    hf = layers.norm_apply(bp["norm2"], x)
    if is_moe:
        yf, aux = moe.moe_apply(bp["ffn"], cfg, hf)
    else:
        yf = layers.mlp_apply(bp["ffn"], hf, cfg.mlp)
    return x + yf, new_cache, aux


def _run_stack(blocks, cfg, x, ctx, mode, cache=None, *, encoder=False):
    """Scan the stacked groups. Returns (x, aux, new_cache|None)."""
    kinds = (("attn", False),) * 1 if encoder else cfg.layer_kinds()
    if encoder:
        kinds = (("attn", False),)

    def group_body(carry, xs):
        x, aux = carry
        bp = xs[0] if isinstance(xs, tuple) else xs
        cache_g = xs[1] if isinstance(xs, tuple) else None
        new_cache_g = {}
        for i, (kind, is_moe) in enumerate(kinds):
            sub = bp[f"p{i}"]
            c_in = None
            if mode == "decode":
                c_in = cache_g[f"p{i}"]
            elif mode == "prefill":
                c_in = ctx["max_len"]  # scalar buffer size for cache alloc
            if encoder:
                h = layers.norm_apply(sub["norm1"], x)
                y, _ = attention.attn_forward(
                    sub["mixer"], cfg, h, ctx["cos"], ctx["sin"], causal=False
                )
                x = x + y
                hf = layers.norm_apply(sub["norm2"], x)
                x = x + layers.mlp_apply(sub["ffn"], hf, cfg.mlp)
                a = jnp.zeros((), jnp.float32)
                nc = None
            else:
                x, nc, a = _block(sub, cfg, kind, is_moe, x, ctx, c_in, mode)
            aux = aux + a
            if nc is not None:
                new_cache_g[f"p{i}"] = nc
        ys = new_cache_g if new_cache_g else jnp.zeros(())
        return (x, aux), ys

    carry0 = (x, jnp.zeros((), jnp.float32))
    xs = blocks if mode != "decode" else (blocks, cache)
    body = group_body
    if mode == "train":
        # activation checkpointing per scanned group: O(G) residual stream
        # saves instead of O(G x per-layer activations) for the backward.
        body = jax.checkpoint(group_body)
    (x, aux), ys = jax.lax.scan(body, carry0, xs)
    new_cache = ys if mode in ("prefill", "decode") else None
    return x, aux, new_cache


# ================================================================ public


def embed_inputs(params, cfg, batch):
    """Token embedding + optional multimodal stub prefixes.

    Returns (x, text_offset): loss applies from text_offset onward.
    """
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    offset = 0
    if cfg.vision_prefix:
        v = batch["vision_embeds"].astype(x.dtype)  # (B, P, d) stub patches
        x = jnp.concatenate([v, x], axis=1)
        offset = v.shape[1]
    return x, offset


def forward(params, cfg: ModelConfig, batch, *, mode: str = "train",
            max_len: int = 0):
    """Full-sequence forward. mode: "train" | "prefill"."""
    x, text_offset = embed_inputs(params, cfg, batch)
    B, T = x.shape[0], x.shape[1]
    cos, sin = _rope_for(cfg, batch, B, T)
    if cfg.rope_style == "none":
        x = x + _sinusoid(jnp.arange(T), cfg.d_model).astype(x.dtype)[None]

    enc = None
    if cfg.encoder_layers:
        enc = batch["enc_embeds"].astype(x.dtype)  # stub frame embeddings
        ectx = {"cos": None, "sin": None, "pos": None, "window": 0,
                "enc": None, "max_len": 0}
        enc, _, _ = _run_stack(params["encoder"]["blocks"], cfg, enc, ectx,
                               "train", encoder=True)
        enc = layers.norm_apply(params["encoder"]["final_norm"], enc)

    ctx = {"cos": cos, "sin": sin, "pos": None, "window": cfg.sliding_window,
           "enc": enc, "max_len": max(max_len, T)}
    x, aux, cache = _run_stack(params["blocks"], cfg, x, ctx, mode)
    x = layers.norm_apply(params["final_norm"], x)
    logits = unembed(params, cfg, x)
    if mode == "prefill":
        return logits, aux, cache
    return logits, aux, text_offset


def unembed(params, cfg, x):
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["lm_head"]


def hidden_forward(params, cfg: ModelConfig, batch):
    """Forward up to the final norm, WITHOUT the unembed projection."""
    x, text_offset = embed_inputs(params, cfg, batch)
    B, T = x.shape[0], x.shape[1]
    cos, sin = _rope_for(cfg, batch, B, T)
    if cfg.rope_style == "none":
        x = x + _sinusoid(jnp.arange(T), cfg.d_model).astype(x.dtype)[None]
    enc = None
    if cfg.encoder_layers:
        enc = batch["enc_embeds"].astype(x.dtype)
        ectx = {"cos": None, "sin": None, "pos": None, "window": 0,
                "enc": None, "max_len": 0}
        enc, _, _ = _run_stack(params["encoder"]["blocks"], cfg, enc, ectx,
                               "train", encoder=True)
        enc = layers.norm_apply(params["encoder"]["final_norm"], enc)
    ctx = {"cos": cos, "sin": sin, "pos": None, "window": cfg.sliding_window,
           "enc": enc, "max_len": T}
    x, aux, _ = _run_stack(params["blocks"], cfg, x, ctx, "train")
    return layers.norm_apply(params["final_norm"], x), aux, text_offset


def _chunked_ce(params, cfg, x_pred, labels):
    """Cross-entropy with the unembed applied chunk-by-chunk over tokens,
    so the (B, T, V) logits never materialize (cfg.loss_chunk, §Perf)."""
    B, T, d = x_pred.shape
    L = cfg.loss_chunk
    pad = (-T) % L
    mask = jnp.concatenate([jnp.ones((B, T), jnp.float32),
                            jnp.zeros((B, pad), jnp.float32)], 1)
    if pad:
        x_pred = jnp.concatenate([x_pred, jnp.zeros((B, pad, d), x_pred.dtype)], 1)
        labels = jnp.concatenate([labels, jnp.zeros((B, pad), labels.dtype)], 1)
    n = (T + pad) // L

    def chunk(carry, xs):
        xc, yc, mc = xs  # (B, L, d), (B, L), (B, L)
        logits = unembed(params, cfg, xc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        iota = jnp.arange(logits.shape[-1], dtype=yc.dtype)
        ll = jnp.sum(jnp.where(iota == yc[..., None], logits, 0.0), axis=-1)
        return carry + jnp.sum((logz - ll) * mc), None

    def split(t):
        return jnp.moveaxis(t.reshape(B, n, L, *t.shape[2:]), 1, 0)

    total, _ = jax.lax.scan(
        jax.checkpoint(chunk), jnp.zeros((), jnp.float32),
        (split(x_pred), split(labels), split(mask)),
    )
    return total / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(params, cfg: ModelConfig, batch):
    """Next-token cross-entropy (+ MoE aux). Returns scalar f32."""
    tokens = batch["tokens"]
    if cfg.loss_chunk:
        x, aux, off = hidden_forward(params, cfg, batch)
        x_pred = x[:, off:-1] if off else x[:, :-1]
        return _chunked_ce(params, cfg, x_pred, tokens[:, 1:]) + aux
    logits, aux, off = forward(params, cfg, batch, mode="train")
    # predict tokens[1:] from positions [off .. off+T-2]
    pred = logits[:, off:-1] if off else logits[:, :-1]
    ce = layers.softmax_cross_entropy(pred, tokens[:, 1:], batch.get("loss_mask"))
    return ce + aux


def decode_step(params, cfg: ModelConfig, token, cache, pos, batch_extras=None):
    """One-token decode. token (B,1) int32; pos scalar int32 absolute position.

    Returns (logits (B,1,V), new_cache).
    """
    x = params["embed"][token]
    B = x.shape[0]
    if cfg.rope_style == "none":
        x = x + _sinusoid(pos[None], cfg.d_model).astype(x.dtype)[None]
        cos = sin = None
    else:
        batch = batch_extras or {}
        cos, sin = _rope_for(cfg, batch, B, 1, offset=pos)
    ctx = {"cos": cos, "sin": sin, "pos": pos, "window": cfg.sliding_window,
           "enc": None, "max_len": 0}
    x, _, cache = _run_stack(params["blocks"], cfg, x, ctx, "decode", cache)
    x = layers.norm_apply(params["final_norm"], x)
    return unembed(params, cfg, x), cache


def init_cache(cfg: ModelConfig, B: int, max_len: int) -> PyTree:
    """Zero-initialized decode cache (leaves stacked over groups)."""
    G = cfg.num_pattern_groups
    S = min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len
    out = {}
    for i, (kind, _) in enumerate(cfg.layer_kinds()):
        if kind == "attn":
            if cfg.mla:
                m = cfg.mla
                c = {
                    "ckv": jnp.zeros((G, B, max_len, m.kv_lora_rank), cfg.jdtype),
                    "krope": jnp.zeros((G, B, max_len, m.rope_head_dim), cfg.jdtype),
                }
            else:
                c = {
                    "k": jnp.zeros((G, B, S, cfg.num_kv_heads, cfg.hd), cfg.jdtype),
                    "v": jnp.zeros((G, B, S, cfg.num_kv_heads, cfg.hd), cfg.jdtype),
                }
            if cfg.encoder_layers:
                c["cross_k"] = jnp.zeros(
                    (G, B, cfg.encoder_len, cfg.num_kv_heads, cfg.hd), cfg.jdtype)
                c["cross_v"] = jnp.zeros_like(c["cross_k"])
        elif kind == "mamba":
            st = mamba.init_state(cfg, B)
            c = jax.tree.map(lambda a: jnp.zeros((G,) + a.shape, a.dtype), st)
        elif kind == "rwkv":
            st = rwkv6.init_state(cfg, B)
            c = jax.tree.map(lambda a: jnp.zeros((G,) + a.shape, a.dtype), st)
        else:
            raise ValueError(kind)
        out[f"p{i}"] = c
    return out


# ========================================================== param count


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = jax.eval_shape(
        functools.partial(init_params, cfg=cfg), jax.random.key(0)
    )
    total = 0
    moe_names = ("w_gate", "w_up", "w_down")
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        n = int(np.prod(leaf.shape))
        if (
            active_only
            and cfg.moe is not None
            and "ffn" in keys
            and keys[-1] in moe_names
            and leaf.ndim == 4  # (G, E, d_in, d_out) stacked routed experts
        ):
            n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        total += n
    return total
