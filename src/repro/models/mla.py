"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

K/V are compressed into a shared latent c_kv (kv_lora_rank) plus one shared
RoPE key head. Decode runs in the *absorbed* form: the cache holds only
(c_kv, k_rope) — O(kv_lora_rank + rope_dim) bytes per token — and the
up-projections W_uk / W_uv are folded into the query/output sides. This is
what makes `long_500k` decode feasible for the 236B model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

NEG_INF = -1e30


def mla_init(key, cfg) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    dt = cfg.jdtype
    ks = jax.random.split(key, 8)
    p = {
        "wkv_a": layers.dense_init(ks[0], d, m.kv_lora_rank + m.rope_head_dim, dt),
        "kv_norm": layers.norm_init(m.kv_lora_rank, "rmsnorm", dt),
        "wk_b": layers.dense_init(ks[1], m.kv_lora_rank, H * m.nope_head_dim, dt),
        "wv_b": layers.dense_init(ks[2], m.kv_lora_rank, H * m.v_head_dim, dt),
        "wo": layers.dense_init(ks[3], H * m.v_head_dim, d, dt),
    }
    q_out = H * (m.nope_head_dim + m.rope_head_dim)
    if m.q_lora_rank:
        p["wq_a"] = layers.dense_init(ks[4], d, m.q_lora_rank, dt)
        p["q_norm"] = layers.norm_init(m.q_lora_rank, "rmsnorm", dt)
        p["wq_b"] = layers.dense_init(ks[5], m.q_lora_rank, q_out, dt)
    else:
        p["wq"] = layers.dense_init(ks[4], d, q_out, dt)
    return p


def _queries(p, cfg, x, cos, sin):
    m = cfg.mla
    H = cfg.num_heads
    if m.q_lora_rank:
        q = layers.norm_apply(p["q_norm"], x @ p["wq_a"]) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(x.shape[0], x.shape[1], H, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = layers.rope_apply(q_rope, cos, sin)
    return q_nope, q_rope


def _latents(p, cfg, x, cos, sin):
    m = cfg.mla
    kv = x @ p["wkv_a"]
    c_kv, k_rope = kv[..., : m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    c_kv = layers.norm_apply(p["kv_norm"], c_kv)
    k_rope = layers.rope_apply(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return c_kv, k_rope


def mla_forward(p: dict, cfg, x: jax.Array, cos, sin, *,
                return_cache: bool = False, max_len: int = 0):
    """Train/prefill: expanded (non-absorbed) attention over the sequence."""
    m = cfg.mla
    B, T, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _queries(p, cfg, x, cos, sin)
    c_kv, k_rope = _latents(p, cfg, x, cos, sin)
    k_nope = (c_kv @ p["wk_b"]).reshape(B, T, H, m.nope_head_dim)
    v = (c_kv @ p["wv_b"]).reshape(B, T, H, m.v_head_dim)

    scale = 1.0 / jnp.sqrt(float(m.nope_head_dim + m.rope_head_dim))
    s = jnp.einsum("bthe,bshe->bhts", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
    s += jnp.einsum("bthe,bse->bhts", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
    if cfg.act_constrain:
        from repro.models import sharding as shmod

        s = shmod.constrain(s, "batch", "model", None, None)
    mask = jnp.arange(T)[None, :] <= jnp.arange(T)[:, None]
    probs = jax.nn.softmax(jnp.where(mask, s * scale, NEG_INF), axis=-1)
    out = jnp.einsum("bhts,bshe->bthe", probs, v.astype(jnp.float32))
    if cfg.act_constrain:
        out = shmod.constrain(out, "batch", None, "model", None)
    y = out.reshape(B, T, H * m.v_head_dim).astype(x.dtype) @ p["wo"]

    cache = None
    if return_cache:
        assert max_len >= T
        ck = jnp.zeros((B, max_len, m.kv_lora_rank), c_kv.dtype)
        cr = jnp.zeros((B, max_len, m.rope_head_dim), k_rope.dtype)
        cache = {
            "ckv": jax.lax.dynamic_update_slice(ck, c_kv, (0, 0, 0)),
            "krope": jax.lax.dynamic_update_slice(cr, k_rope, (0, 0, 0)),
        }
    return y, cache


def mla_decode(p: dict, cfg, x: jax.Array, cache: dict, pos, cos, sin):
    """Absorbed single-token decode against the latent cache."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    q_nope, q_rope = _queries(p, cfg, x, cos, sin)  # (B,1,H,*)
    c_kv, k_rope = _latents(p, cfg, x, cos, sin)  # (B,1,r), (B,1,rd)
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], c_kv, (0, pos, 0))
    krope = jax.lax.dynamic_update_slice(cache["krope"], k_rope, (0, pos, 0))

    # absorb W_uk into the query: q_lat (B,1,H,r)
    wk_b = p["wk_b"].reshape(m.kv_lora_rank, H, m.nope_head_dim)
    q_lat = jnp.einsum("bthe,rhe->bthr", q_nope.astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    s = jnp.einsum("bthr,bsr->bhts", q_lat, ckv.astype(jnp.float32))
    s += jnp.einsum("bthe,bse->bhts", q_rope.astype(jnp.float32),
                    krope.astype(jnp.float32))
    scale = 1.0 / jnp.sqrt(float(m.nope_head_dim + m.rope_head_dim))
    valid = jnp.arange(ckv.shape[1]) <= pos
    probs = jax.nn.softmax(
        jnp.where(valid[None, None, None, :], s * scale, NEG_INF), axis=-1
    )
    out_lat = jnp.einsum("bhts,bsr->bthr", probs, ckv.astype(jnp.float32))
    wv_b = p["wv_b"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bthr,rhe->bthe", out_lat, wv_b.astype(jnp.float32))
    y = out.reshape(B, 1, H * m.v_head_dim).astype(x.dtype) @ p["wo"]
    return y, {"ckv": ckv, "krope": krope}
