"""Common neural-net building blocks (pure functional, no framework).

Conventions:
  * params are nested dicts of jnp arrays;
  * init_* functions take a PRNG key and return a param subtree;
  * apply functions are pure: (params, x, ...) -> y;
  * compute dtype follows the input; norm statistics and softmax in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------- norms


def norm_init(d: int, kind: str, dtype) -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    raise ValueError(kind)


def norm_apply(p: dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in p:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def groupnorm_heads(x: jax.Array, scale: jax.Array, bias: jax.Array) -> jax.Array:
    """LayerNorm within each head: x (..., H, hd)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
    y = y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------- MLPs


def mlp_init(key, d: int, d_ff: int, kind: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, d, d_ff, dtype),
            "w_up": dense_init(k2, d, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d, dtype),
        }
    if kind in ("gelu", "relu_sq"):
        return {
            "w_up": dense_init(k1, d, d_ff, dtype),
            "w_down": dense_init(k2, d_ff, d, dtype),
        }
    raise ValueError(kind)


def mlp_apply(p: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif kind == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif kind == "gelu":
        h = jax.nn.gelu(x @ p["w_up"])
    elif kind == "relu_sq":
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
    else:
        raise ValueError(kind)
    return h @ p["w_down"]


# ----------------------------------------------------------------- RoPE


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float):
    """positions (..., T) int -> cos/sin (..., T, head_dim//2) f32."""
    freqs = jnp.asarray(rope_freqs(head_dim, theta), jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions: jax.Array, head_dim: int, theta: float, sections):
    """Qwen2-VL multimodal RoPE [arXiv:2409.12191].

    positions: (3, B, T) — temporal / height / width position streams.
    sections: split of head_dim//2 among the three streams.
    Returns cos/sin of shape (B, T, head_dim//2).
    """
    assert positions.shape[0] == 3
    cos3, sin3 = rope_cos_sin(positions, head_dim, theta)  # (3,B,T,hd/2)
    secs = np.cumsum(np.asarray(sections))[:-1]
    cos_parts = jnp.split(cos3, secs, axis=-1)
    sin_parts = jnp.split(sin3, secs, axis=-1)
    cos = jnp.concatenate([cos_parts[i][i] for i in range(3)], axis=-1)
    sin = jnp.concatenate([sin_parts[i][i] for i in range(3)], axis=-1)
    return cos, sin


def rope_apply(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, T, H, hd); cos/sin (B, T, hd//2) or (T, hd//2)."""
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    if cos.ndim == 2:
        cos = cos[None]
        sin = sin[None]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    y = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return y.astype(x.dtype)


# ----------------------------------------------------------------- loss


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array, mask=None) -> jax.Array:
    """Mean token cross-entropy, f32. logits (..., V), labels (...) int.

    The label pick uses a masked reduction rather than take_along_axis: a
    gather over a vocab-sharded logits tensor makes GSPMD all-gather the
    full (B, T, V) array, while select+reduce stays shard-local.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
    picked = jnp.where(vocab_iota == labels[..., None], logits, 0.0)
    ll = jnp.sum(picked, axis=-1)
    nll = logz - ll
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
