"""Parameter/activation PartitionSpec rules for the production mesh.

Mesh axes: ("pod", "data", "model") multi-pod or ("data", "model") single
pod. Tensor parallelism lives on "model"; "data" is the client axis
(parallel FL mode) or the FSDP axis (sequential mode / big-model serving);
"pod" extends the client/data axis across pods.

Rules are name-based over the param tree; every block leaf carries a
leading scan-group axis which is never sharded. Dims are only sharded when
divisible by the axis size (GSPMD would otherwise pad-and-mask, which
muddies the roofline numbers).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# name -> which logical dim to put on the model axis, counted from the END
# of the non-group dims: "last" = output features, "first" = input features.
_LAST = {
    "w_gate", "w_up", "wq", "wk", "wv", "in_proj", "dt_w", "cw_k", "cw_r",
    "w_r", "w_k", "w_v", "w_g", "wq_b", "wk_b", "wv_b",
}
_FIRST = {"w_down", "wo", "out_proj", "x_proj", "A_log", "cw_v", "w_o"}
_REPLICATE = {
    "router", "decay_a", "decay_b", "u", "w_base", "ln_x_scale", "ln_x_bias",
    "scale", "bias", "conv_b", "dt_b", "D", "b", "kv_norm", "q_norm",
    "mu_r", "mu_k", "mu_v", "mu_g", "mu_w", "cmu_k", "cmu_r", "wq_a", "wkv_a",
}


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def _leaf_spec(keys: tuple, shape: tuple, mesh: Mesh, fsdp: bool,
               replicate_extra: frozenset = frozenset()) -> P:
    model = "model" if "model" in mesh.axis_names else None
    msize = _axis_size(mesh, "model")
    dsize = _axis_size(mesh, "data")
    name = keys[-1]
    if name in replicate_extra:
        return P(*([None] * len(shape)))
    grouped = "blocks" in keys  # leading scan-group axis
    off = 1 if grouped else 0
    nd = len(shape)
    spec: list = [None] * nd

    def try_set(dim: int, axis: str, size: int) -> bool:
        if dim < off or dim >= nd or spec[dim] is not None:
            return False
        if shape[dim] % size != 0 or shape[dim] < size:
            return False
        spec[dim] = axis
        return True

    if model is not None and nd - off >= 2 and name not in _REPLICATE:
        if name in ("w_gate", "w_up", "w_down") and nd - off == 3:
            # stacked routed experts (E, d_in, d_out): expert parallelism
            if not try_set(off, "model", msize):
                try_set(nd - 1, "model", msize)
        elif name == "embed":
            if not try_set(0, "model", msize):  # vocab
                try_set(1, "model", msize)
        elif name == "lm_head":
            if not try_set(1, "model", msize):
                try_set(0, "model", msize)
        elif name == "conv_w":
            try_set(nd - 1, "model", msize)
        elif name in _LAST:
            try_set(nd - 1, "model", msize)
        elif name in _FIRST:
            try_set(nd - 2, "model", msize)

    if fsdp and "data" in mesh.axis_names and nd - off >= 2:
        # shard the largest remaining dim over the data axis
        cand = sorted(range(off, nd), key=lambda d: -shape[d])
        for d in cand:
            if spec[d] is None and try_set(d, "data", dsize):
                break
    return P(*spec)


def param_pspecs(params_or_shapes: PyTree, mesh: Mesh, *, fsdp: bool = False,
                 replicate_extra: frozenset = frozenset()) -> PyTree:
    """PartitionSpec tree matching the param tree.

    replicate_extra: leaf names forced to full replication — e.g. MQA k/v
    projections whose head count cannot fill the model axis (sharding their
    head_dim puts the contraction on the mesh and costs a T x T-score
    all-reduce per layer; replicating them is the cheaper trade).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_or_shapes)
    specs = []
    for path, leaf in flat:
        keys = tuple(getattr(k, "key", getattr(k, "name", "")) for k in path)
        specs.append(_leaf_spec(keys, tuple(leaf.shape), mesh, fsdp,
                                replicate_extra))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params_or_shapes, mesh, *, fsdp: bool = False,
                    replicate_extra: frozenset = frozenset()):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_pspecs(params_or_shapes, mesh, fsdp=fsdp,
                     replicate_extra=replicate_extra),
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_axes(mesh: Mesh) -> tuple:
    """Mesh axes forming the batch/client dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def shard_batch_dim(mesh: Mesh, tree: PyTree, dim_of: Optional[dict] = None,
                    default_dim: int = 0):
    """NamedSharding tree putting the batch axes on `default_dim` of every
    leaf if divisible, else replicating."""
    axes = batch_axes(mesh)
    total = 1
    for a in axes:
        total *= _axis_size(mesh, a)

    def leaf(x):
        spec = [None] * len(x.shape)
        d = default_dim
        if len(x.shape) > d and x.shape[d] % total == 0 and x.shape[d] >= total:
            spec[d] = axes if len(axes) > 1 else axes[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, tree)


def replicated(mesh: Mesh, tree: PyTree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


_CONSTRAINT_MESH: list = [None]


def set_constraint_mesh(mesh) -> None:
    """Register the mesh used by in-model `constrain` calls (set by the
    launch builders before tracing; `with mesh:` alone is not visible to
    traced code in this jax version)."""
    _CONSTRAINT_MESH[0] = mesh


def constrain(x, *axes):
    """Soft in-model activation constraint: `axes` gives one entry per dim —
    None, a mesh axis name, or "batch" (expands to the (pod, data) axes).

    No-op when no constraint mesh is registered (CPU smoke tests) or when a
    dim is not divisible by its axis size, so model code can call this
    unconditionally. Used to stop GSPMD from un-sharding the batch dim of
    attention scores in FSDP mode (see EXPERIMENTS.md §Perf).
    """
    mesh = _CONSTRAINT_MESH[0]
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return x
    names = mesh.axis_names
    spec = []
    for dim, a in enumerate(axes):
        if a == "batch":
            # try the full (pod, data) product, then data-only, then pod-only
            # (a 2-pod mesh with per-client B=16 can still shard 16-way)
            chosen = None
            full = tuple(n for n in ("pod", "data") if n in names)
            for cand in (full, ("data",) if "data" in names else (),
                         ("pod",) if "pod" in names else ()):
                if not cand:
                    continue
                total = 1
                for n in cand:
                    total *= mesh.shape[n]
                if x.shape[dim] % total == 0 and x.shape[dim] >= total:
                    chosen = cand if len(cand) > 1 else cand[0]
                    break
            spec.append(chosen)
        elif a in names and x.shape[dim] % mesh.shape[a] == 0 and x.shape[dim] >= mesh.shape[a]:
            spec.append(a)
        else:
            spec.append(None)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
