"""RWKV-6 "Finch" block: data-dependent-decay linear attention
(arXiv:2404.05892), TPU-adapted.

TPU adaptation (see DESIGN.md §3): instead of a length-T scalar recurrence,
the WKV state is advanced in chunks of `chunk_len`; intra-chunk interactions
become (L×L) matmuls (MXU-friendly) and the state crosses chunk boundaries
through a `lax.scan`. Numerics: with per-channel decay w ∈ (0,1) the chunked
form needs exp(±cumsum(log w)); we clamp the per-step log-decay to
[-40/chunk_len, -1e-6] so every exponent stays within f32 range. All WKV
math runs in f32.

Simplification vs the full paper: the token-shift interpolation for r/k/v/g
uses static learned mixes (RWKV-5 style); the *decay* keeps the paper's
data-dependent LoRA (the headline feature of Finch). Recorded in DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def rwkv_init(key, cfg) -> dict:
    r = cfg.rwkv
    d = cfg.d_model
    H = d // r.head_dim
    dt = cfg.jdtype
    ks = jax.random.split(key, 12)

    def mix(k):
        return jax.random.uniform(k, (d,), jnp.float32).astype(dt)

    p = {
        # time mix
        "mu_r": mix(ks[0]), "mu_k": mix(ks[1]), "mu_v": mix(ks[2]),
        "mu_g": mix(ks[3]), "mu_w": mix(ks[4]),
        "w_r": layers.dense_init(ks[5], d, d, dt),
        "w_k": layers.dense_init(ks[6], d, d, dt),
        "w_v": layers.dense_init(ks[7], d, d, dt),
        "w_g": layers.dense_init(ks[8], d, d, dt),
        "w_o": layers.dense_init(ks[9], d, d, dt),
        # data-dependent decay LoRA: logw = -exp(w_base + tanh(x A) B)
        "decay_a": layers.dense_init(ks[10], d, r.decay_lora, dt),
        "decay_b": (jax.random.normal(ks[11], (r.decay_lora, d), jnp.float32) * 0.01).astype(dt),
        "w_base": jnp.zeros((d,), jnp.float32),
        "u": jnp.zeros((H, r.head_dim), jnp.float32),  # bonus
        "ln_x_scale": jnp.ones((H, r.head_dim), jnp.float32),
        "ln_x_bias": jnp.zeros((H, r.head_dim), jnp.float32),
        # channel mix
        "cmu_k": mix(ks[0]), "cmu_r": mix(ks[1]),
        "cw_k": layers.dense_init(ks[5], d, cfg.d_ff, dt),
        "cw_v": layers.dense_init(ks[6], cfg.d_ff, d, dt),
        "cw_r": layers.dense_init(ks[7], d, d, dt),
    }
    return p


def _shift(x: jax.Array, last: jax.Array | None) -> jax.Array:
    """Token shift: x_{t-1}, with `last` (B, d) as position -1 (zeros if None)."""
    first = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _lerp(x, xs, mu):
    return x + (xs - x) * mu


def _decay_log(p, xw: jax.Array, chunk_len: int) -> jax.Array:
    """Per-channel log-decay in [-40/chunk_len, -1e-6]."""
    lora = jnp.tanh(xw @ p["decay_a"]) @ p["decay_b"]
    logw = -jnp.exp(p["w_base"].astype(jnp.float32) + lora.astype(jnp.float32))
    return jnp.clip(logw, -40.0 / chunk_len, -1e-6)


def _wkv_chunked(r, k, v, logw, u, state):
    """Chunked WKV. r/k/v/logw: (B, T, H, e) f32; u (H, e); state (B, H, e, e).

    Returns (out (B,T,H,e), final_state). T must divide by the chunk length
    already baked into the caller's reshape.
    """
    B, nC, L, H, e = r.shape
    mask = (jnp.arange(L)[:, None] > jnp.arange(L)[None, :]).astype(jnp.float32)

    def body(S, xs):
        rc, kc, vc, lwc = xs  # (B, L, H, e)
        cw = jnp.cumsum(lwc, axis=1)  # inclusive
        cwe = cw - lwc  # exclusive: cw_{t-1}
        r_t = rc * jnp.exp(cwe)
        k_t = kc * jnp.exp(-cw)
        scores = jnp.einsum("blhe,bmhe->bhlm", r_t, k_t) * mask[None, None]
        diag = jnp.einsum("blhe,blhe->bhl", rc, u[None, None] * kc)
        scores = scores + jnp.einsum("bhl,lm->bhlm", diag, jnp.eye(L))
        o_intra = jnp.einsum("bhlm,bmhe->blhe", scores, vc)
        o_inter = jnp.einsum("blhe,bhef->blhf", r_t, S)
        cw_last = cw[:, -1]  # (B, H, e)
        k_carry = kc * jnp.exp(cw_last[:, None] - cw)
        S_new = S * jnp.exp(cw_last)[..., None] + jnp.einsum(
            "blhe,blhf->bhef", k_carry, vc
        )
        return S_new, o_intra + o_inter

    r, k, v, logw = (jnp.moveaxis(t, 1, 0) for t in (r, k, v, logw))
    final, out = jax.lax.scan(body, state, (r, k, v, logw))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nC * L, H, e)
    return out, final


def time_mix(p, cfg, x, state):
    """x (B,T,d) normed input; state None (train) or dict (decode prefix).

    Returns (y, new_state_dict).
    """
    r_cfg = cfg.rwkv
    e = r_cfg.head_dim
    d = cfg.d_model
    H = d // e
    B, T, _ = x.shape
    last = None if state is None else state["tm_last"]
    xs = _shift(x, last)
    rr = _lerp(x, xs, p["mu_r"]) @ p["w_r"]
    kk = _lerp(x, xs, p["mu_k"]) @ p["w_k"]
    vv = _lerp(x, xs, p["mu_v"]) @ p["w_v"]
    gg = jax.nn.silu(_lerp(x, xs, p["mu_g"]) @ p["w_g"])
    logw = _decay_log(p, _lerp(x, xs, p["mu_w"]), r_cfg.chunk_len)

    def heads(t):
        return t.reshape(B, T, H, e).astype(jnp.float32)

    r4, k4, v4, w4 = heads(rr), heads(kk), heads(vv), heads(logw)
    S0 = (
        jnp.zeros((B, H, e, e), jnp.float32)
        if state is None
        else state["S"].astype(jnp.float32)
    )
    L = r_cfg.chunk_len
    assert T % L == 0, f"T={T} not divisible by rwkv chunk_len={L}"
    nC = T // L

    def chunkify(t):
        return t.reshape(B, nC, L, H, e)

    out, S_fin = _wkv_chunked(
        chunkify(r4), chunkify(k4), chunkify(v4), chunkify(w4),
        p["u"].astype(jnp.float32), S0,
    )
    out = layers.groupnorm_heads(out, p["ln_x_scale"], p["ln_x_bias"])
    y = (out.reshape(B, T, d).astype(x.dtype) * gg) @ p["w_o"]
    new_state = {"S": S_fin, "tm_last": x[:, -1]}
    return y, new_state


def time_mix_decode(p, cfg, x, state):
    """Single-token recurrent step. x (B,1,d)."""
    r_cfg = cfg.rwkv
    e = r_cfg.head_dim
    d = cfg.d_model
    H = d // e
    B = x.shape[0]
    xs = state["tm_last"][:, None]
    rr = _lerp(x, xs, p["mu_r"]) @ p["w_r"]
    kk = _lerp(x, xs, p["mu_k"]) @ p["w_k"]
    vv = _lerp(x, xs, p["mu_v"]) @ p["w_v"]
    gg = jax.nn.silu(_lerp(x, xs, p["mu_g"]) @ p["w_g"])
    logw = _decay_log(p, _lerp(x, xs, p["mu_w"]), r_cfg.chunk_len)

    def heads(t):
        return t.reshape(B, H, e).astype(jnp.float32)

    r1, k1, v1 = heads(rr[:, 0]), heads(kk[:, 0]), heads(vv[:, 0])
    w1 = heads(logw[:, 0])
    S = state["S"].astype(jnp.float32)  # (B,H,e,e)
    u = p["u"].astype(jnp.float32)
    wkv = S + (u[None] * k1)[..., None] * v1[..., None, :]
    o = jnp.einsum("bhe,bhef->bhf", r1, wkv)  # (B,H,e)
    S_new = S * jnp.exp(w1)[..., None] + k1[..., None] * v1[..., None, :]
    o = layers.groupnorm_heads(o, p["ln_x_scale"], p["ln_x_bias"])
    y = (o.reshape(B, 1, d).astype(x.dtype) * gg) @ p["w_o"]
    return y, {"S": S_new, "tm_last": x[:, -1]}


def channel_mix(p, x, last):
    """RWKV channel mix (relu^2). last: (B,d) or None. Returns (y, new_last)."""
    xs = _shift(x, last)
    k = _lerp(x, xs, p["cmu_k"]) @ p["cw_k"]
    kv = jnp.square(jax.nn.relu(k)) @ p["cw_v"]
    r = jax.nn.sigmoid(_lerp(x, xs, p["cmu_r"]) @ p["cw_r"])
    return r * kv, x[:, -1]


def init_state(cfg, B: int) -> dict:
    e = cfg.rwkv.head_dim
    H = cfg.d_model // e
    return {
        "S": jnp.zeros((B, H, e, e), jnp.float32),
        "tm_last": jnp.zeros((B, cfg.d_model), cfg.jdtype),
        "cm_last": jnp.zeros((B, cfg.d_model), cfg.jdtype),
    }
