"""The paper's own evaluation models (Section V):

* MLR — multinomial logistic regression on flattened 28x28 images (convex).
* CNN — 5x5x32 conv > 2x2 maxpool > 5x5x64 conv > 2x2 maxpool >
  FC(3136->512) > FC(512->10); 1,663,370 parameters, matching the paper's
  stated total (its "1024x512" FC is a typo — 7*7*64=3136 inputs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlr_init(key, num_classes: int = 10, side: int = 28) -> dict:
    d = side * side
    return {
        "w": jax.random.normal(key, (d, num_classes), jnp.float32) * 0.01,
        "b": jnp.zeros((num_classes,), jnp.float32),
    }


def mlr_apply(params: dict, x: jax.Array) -> jax.Array:
    return x.reshape(x.shape[0], -1) @ params["w"] + params["b"]


def cnn_init(key, num_classes: int = 10) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def conv(k, kh, kw, cin, cout):
        scale = 1.0 / jnp.sqrt(kh * kw * cin)
        return jax.random.normal(k, (kh, kw, cin, cout), jnp.float32) * scale

    def fc(k, a, b):
        return jax.random.normal(k, (a, b), jnp.float32) / jnp.sqrt(a)

    return {
        "c1": conv(k1, 5, 5, 1, 32), "b1": jnp.zeros((32,)),
        "c2": conv(k2, 5, 5, 32, 64), "b2": jnp.zeros((64,)),
        "f1": fc(k3, 3136, 512), "fb1": jnp.zeros((512,)),
        "f2": fc(k4, 512, num_classes), "fb2": jnp.zeros((num_classes,)),
    }


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_apply(params: dict, x: jax.Array) -> jax.Array:
    """x (B, 28, 28, 1) -> logits (B, 10)."""
    h = jax.lax.conv_general_dilated(
        x, params["c1"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    ) + params["b1"]
    h = _maxpool2(jax.nn.relu(h))
    h = jax.lax.conv_general_dilated(
        h, params["c2"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    ) + params["b2"]
    h = _maxpool2(jax.nn.relu(h))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["f1"] + params["fb1"])
    return h @ params["f2"] + params["fb2"]


MODELS = {
    "mlr": (mlr_init, mlr_apply),
    "cnn": (cnn_init, cnn_apply),
}


def classification_loss(apply_fn, params, x, y):
    logits = apply_fn(params, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - ll)


def accuracy(apply_fn, params, x, y, batch: int = 2048) -> float:
    correct = 0
    for i in range(0, x.shape[0], batch):
        logits = apply_fn(params, x[i : i + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i : i + batch]))
    return correct / x.shape[0]
