"""GQA/MQA attention with RoPE, sliding-window option, and KV-cache decode.

Cache layout (per layer): {"k": (B, S, G, hd), "v": (B, S, G, hd)} with S =
max_len for full attention or S = window for the sliding-window ring buffer.
Keys are stored *already rotated*; decode only rotates the query.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

NEG_INF = -1e30


def attn_init(key, cfg, d_in: int | None = None) -> dict:
    d = d_in or cfg.d_model
    hd = cfg.hd
    kq, kk, kv, ko = jax.random.split(key, 4)
    dt = cfg.jdtype
    return {
        "wq": layers.dense_init(kq, d, cfg.num_heads * hd, dt),
        "wk": layers.dense_init(kk, d, cfg.num_kv_heads * hd, dt),
        "wv": layers.dense_init(kv, d, cfg.num_kv_heads * hd, dt),
        "wo": layers.dense_init(ko, cfg.num_heads * hd, d, dt),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _gqa_scores(q, k, scale):
    """q (B,T,H,hd), k (B,S,G,hd) -> scores (B,G,H/G,T,S) in f32."""
    B, T, H, hd = q.shape
    G = k.shape[2]
    q = q.reshape(B, T, G, H // G, hd)
    return jnp.einsum(
        "btghe,bsge->bghts", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale


def _gqa_out(probs, v):
    """probs (B,G,Hg,T,S), v (B,S,G,hd) -> (B,T,H*hd)."""
    B, G, Hg, T, S = probs.shape
    out = jnp.einsum("bghts,bsge->btghe", probs, v.astype(jnp.float32))
    return out.reshape(B, T, G * Hg * v.shape[-1])


def _masked_softmax(scores, mask):
    scores = jnp.where(mask, scores, NEG_INF)
    return jax.nn.softmax(scores, axis=-1)


def full_mask(T: int, S: int, causal: bool, window: int, offset: int = 0):
    """(T, S) bool mask. `offset` = absolute position of query 0 minus key 0."""
    qi = jnp.arange(T)[:, None] + offset
    kj = jnp.arange(S)[None, :]
    m = jnp.ones((T, S), bool)
    if causal:
        m &= kj <= qi
    if window:
        m &= kj > qi - window
    return m


def attn_forward(p: dict, cfg, x: jax.Array, cos, sin, *, causal: bool = True,
                 window: int = 0, return_cache: bool = False, max_len: int = 0):
    """Full-sequence attention (train / prefill).

    Returns (y, cache|None). For prefill, `max_len` sizes the cache buffer
    (>= T for full attention; ring of size `window` for SWA).

    With cfg.q_chunk > 0 the score/softmax/AV contraction is computed one
    query block at a time (lax.scan), bounding the live score tensor to
    B*H*q_chunk*T f32 instead of B*H*T^2 — the §Perf memory-term
    optimization for long-sequence training.
    """
    B, T, _ = x.shape
    hd = cfg.hd
    q = _split_heads(x @ p["wq"], cfg.num_heads, hd)
    k = _split_heads(x @ p["wk"], cfg.num_kv_heads, hd)
    v = _split_heads(x @ p["wv"], cfg.num_kv_heads, hd)
    if cos is not None:
        q = layers.rope_apply(q, cos, sin)
        k = layers.rope_apply(k, cos, sin)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    q_chunk = getattr(cfg, "q_chunk", 0)
    use_flash = (
        getattr(cfg, "attention_impl", "xla") == "flash"
        and window == 0 and causal and T % 64 == 0
    )
    if use_flash:
        from repro.kernels import flash_attn

        blk = min(128, T)
        out = flash_attn.gqa_flash(q, k, v, causal=True, blk_q=blk, blk_k=blk,
                                   interpret=jax.default_backend() != "tpu")
        y = out.reshape(B, T, cfg.num_heads * hd).astype(x.dtype) @ p["wo"]
    elif q_chunk and T > q_chunk and T % q_chunk == 0:
        out = _chunked_attention(q, k, v, scale, causal, window, q_chunk)
        y = out.astype(x.dtype) @ p["wo"]
    else:
        scores = _gqa_scores(q, k, scale)
        if getattr(cfg, "act_constrain", False):
            from repro.models import sharding as shmod

            # keep batch on the data axes through the score tensor — GSPMD
            # otherwise un-shards it under FSDP param sharding (§Perf)
            scores = shmod.constrain(scores, "batch", "model", None, None, None)
        mask = full_mask(T, T, causal, window)
        probs = _masked_softmax(scores, mask)
        y = _gqa_out(probs, v).astype(x.dtype) @ p["wo"]

    cache = None
    if return_cache:
        S = min(window, max_len) if window else max_len
        assert S > 0
        ck = jnp.zeros((B, S, cfg.num_kv_heads, hd), k.dtype)
        cv = jnp.zeros((B, S, cfg.num_kv_heads, hd), v.dtype)
        if window and T > S:
            # ring buffer keeps the trailing `window` positions, rotated so
            # that slot = pos % S matches decode-time writes.
            tail_k, tail_v = k[:, -S:], v[:, -S:]
            shift = T % S
            tail_k = jnp.roll(tail_k, shift, axis=1)
            tail_v = jnp.roll(tail_v, shift, axis=1)
            ck, cv = tail_k, tail_v
        else:
            ck = jax.lax.dynamic_update_slice(ck, k[:, -min(T, S):], (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v[:, -min(T, S):], (0, 0, 0, 0))
        cache = {"k": ck, "v": cv}
    return y, cache


def attn_decode(p: dict, cfg, x: jax.Array, cache: dict, pos, cos, sin, *,
                window: int = 0):
    """Single-token decode. x (B,1,d); pos: scalar int32 absolute position.

    Returns (y, new_cache).
    """
    B = x.shape[0]
    hd = cfg.hd
    S = cache["k"].shape[1]
    q = _split_heads(x @ p["wq"], cfg.num_heads, hd)
    k = _split_heads(x @ p["wk"], cfg.num_kv_heads, hd)
    v = _split_heads(x @ p["wv"], cfg.num_kv_heads, hd)
    if cos is not None:
        q = layers.rope_apply(q, cos, sin)
        k = layers.rope_apply(k, cos, sin)
    slot = (pos % S) if window else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))

    scores = _gqa_scores(q, ck, 1.0 / jnp.sqrt(hd).astype(jnp.float32))  # (B,G,Hg,1,S)
    idx = jnp.arange(S)
    if window:
        valid = idx < jnp.minimum(pos + 1, S)  # ring: everything written is in-window
    else:
        valid = idx <= pos
    probs = _masked_softmax(scores, valid[None, None, None, None, :])
    y = _gqa_out(probs, cv).astype(x.dtype) @ p["wo"]
    return y, {"k": ck, "v": cv}


def _chunked_attention(q, k, v, scale, causal, window, q_chunk):
    """Query-blocked attention: scan over query chunks, full K/V visible.

    Live memory per step: (B, G, Hg, q_chunk, T) f32 scores — T/q_chunk x
    smaller than the naive path. Returns (B, T, H*hd) f32.
    """
    B, T, H, hd = q.shape
    n = T // q_chunk
    qs = jnp.moveaxis(q.reshape(B, n, q_chunk, H, hd), 1, 0)

    def body(_, xs):
        qb, i = xs
        scores = _gqa_scores(qb, k, scale)
        mask = full_mask(q_chunk, T, causal, window, offset=i * q_chunk)
        probs = _masked_softmax(scores, mask)
        return None, _gqa_out(probs, v)

    _, outs = jax.lax.scan(body, None, (qs, jnp.arange(n)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, T, H * hd)


# ------------------------------------------------------- cross-attention


def cross_attn_init(key, cfg) -> dict:
    return attn_init(key, cfg)


def cross_attn_kv(p: dict, cfg, enc: jax.Array) -> dict:
    """Precompute encoder K/V once (prefill); reused for every decode step."""
    hd = cfg.hd
    k = _split_heads(enc @ p["wk"], cfg.num_kv_heads, hd)
    v = _split_heads(enc @ p["wv"], cfg.num_kv_heads, hd)
    return {"k": k, "v": v}

def cross_attn_apply(p: dict, cfg, x: jax.Array, kv: dict) -> jax.Array:
    hd = cfg.hd
    q = _split_heads(x @ p["wq"], cfg.num_heads, hd)
    scores = _gqa_scores(q, kv["k"], 1.0 / jnp.sqrt(hd).astype(jnp.float32))
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, kv["v"]).astype(x.dtype) @ p["wo"]
