"""Mixture-of-Experts FFN (DeepSeek-V2 style: shared + routed, top-k).

Dispatch is sort-based with a fixed per-expert capacity (drop-on-overflow),
so compiled FLOPs track *activated* parameters (E·C ≈ tokens·top_k·cap):
tokens are argsorted by expert id, packed into an (E, C, d) buffer, run
through a stacked-expert grouped matmul, and combined back with their gate
weights. Expert weights are stacked on a leading E axis so the tensor-
parallel mesh axis shards *experts* (expert parallelism).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def moe_init(key, cfg) -> dict:
    m = cfg.moe
    d, dt = cfg.d_model, cfg.jdtype
    kr, ke, ks = jax.random.split(key, 3)
    ek = jax.random.split(ke, 3)
    E, f = m.num_experts, m.d_ff_expert

    def stacked(k, a, b):
        kk = jax.random.split(k, E)
        return jax.vmap(lambda q: layers.dense_init(q, a, b, dt))(kk)

    p = {
        "router": layers.dense_init(kr, d, E, jnp.float32),
        "w_gate": stacked(ek[0], d, f),
        "w_up": stacked(ek[1], d, f),
        "w_down": stacked(ek[2], f, d),
    }
    if m.num_shared:
        p["shared"] = layers.mlp_init(ks, d, m.num_shared * f, "swiglu", dt)
    return p


def _capacity(num_tokens: int, m) -> int:
    c = int(num_tokens * m.top_k * m.capacity_factor / m.num_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_apply(p: dict, cfg, x: jax.Array):
    """x (B, T, d) -> (y, aux_loss). Also handles (B, 1, d) decode."""
    m = cfg.moe
    B, T, d = x.shape
    N = B * T
    xf = x.reshape(N, d)

    logits = (xf.astype(jnp.float32)) @ p["router"]  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, m.top_k)  # (N, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    C = _capacity(N, m)
    E = m.num_experts
    flat_e = eidx.reshape(-1)  # (N*k,)
    flat_tok = jnp.repeat(jnp.arange(N), m.top_k)
    flat_gate = gates.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se, stok, sgate = flat_e[order], flat_tok[order], flat_gate[order]
    pos_in_e = jnp.arange(N * m.top_k) - jnp.searchsorted(se, se, side="left")
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)  # overflow row dropped

    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(xf[stok])
    h = buf[: E * C].reshape(E, C, d)
    # grouped swiglu over stacked experts
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", h, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"]).reshape(E * C, d)
    ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], axis=0)

    contrib = ye[slot] * (sgate * keep).astype(ye.dtype)[:, None]
    acc_dt = jnp.dtype(m.combine_dtype)
    y = jnp.zeros((N, d), acc_dt).at[stok].add(contrib.astype(acc_dt))
    y = y.astype(x.dtype)

    if "shared" in p:
        y = y + layers.mlp_apply(p["shared"], xf, "swiglu")

    # switch-style load-balance loss over all k assignments
    f_e = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (N * m.top_k)
    p_e = jnp.mean(probs, axis=0)
    aux = m.aux_loss_weight * E * jnp.sum(f_e * p_e)
    return y.reshape(B, T, d), aux
