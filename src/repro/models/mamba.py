"""Mamba-1 selective SSM block (arXiv:2312.00752), as used by Jamba
(arXiv:2403.19887).

The selective scan keeps Mamba-1's full (d_inner × d_state) data-dependent
decay, so it is advanced with a `lax.scan` over time (the separable chunked
trick used for RWKV-6 does not apply when the decay varies per (channel,
state) pair — see DESIGN.md §3). State math in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers


def _dt_rank(cfg) -> int:
    return cfg.ssm.dt_rank or -(-cfg.d_model // 16)


def d_inner(cfg) -> int:
    return cfg.ssm.expand * cfg.d_model


def mamba_init(key, cfg) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = d_inner(cfg)
    dtr = _dt_rank(cfg)
    dt = cfg.jdtype
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    A = np.tile(np.arange(1, s.d_state + 1, dtype=np.float32), (di, 1))
    return {
        "in_proj": layers.dense_init(ks[0], d, 2 * di, dt),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, 1, di), jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": layers.dense_init(ks[2], di, dtr + 2 * s.d_state, dt),
        "dt_w": layers.dense_init(ks[3], dtr, di, dt),
        "dt_b": jnp.full((di,), np.log(np.expm1(0.01)), jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.asarray(np.log(A)),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": layers.dense_init(ks[4], di, d, dt),
    }


def _conv_causal(x: jax.Array, w: jax.Array, b: jax.Array, buf=None):
    """Depthwise causal conv. x (B,T,di); w (K,1,di). buf (B,K-1,di) decode
    prefix or None (zero history). Returns (y, new_buf)."""
    K = w.shape[0]
    prefix = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype) if buf is None else buf
    xp = jnp.concatenate([prefix, x], axis=1)
    y = jax.lax.conv_general_dilated(
        xp, w, window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[2],
    )
    return y + b, xp[:, -(K - 1):]


def _ssm_params(p, cfg, x_c):
    """x_c (B,T,di) -> dt (B,T,di), Bm/Cm (B,T,n) in f32."""
    s = cfg.ssm
    dtr = _dt_rank(cfg)
    proj = (x_c @ p["x_proj"]).astype(jnp.float32)
    dt_in, Bm, Cm = jnp.split(proj, [dtr, dtr + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_w"].astype(jnp.float32) + p["dt_b"])
    return dt, Bm, Cm


def mamba_forward(p: dict, cfg, x: jax.Array, state: dict | None):
    """x (B,T,d). state: None or {"h": (B,di,n), "conv": (B,K-1,di)}.

    Returns (y (B,T,d), new_state).
    """
    di = d_inner(cfg)
    xz = x @ p["in_proj"]
    x_in, z = xz[..., :di], xz[..., di:]
    buf = None if state is None else state["conv"]
    x_c, new_buf = _conv_causal(x_in, p["conv_w"], p["conv_b"], buf)
    x_c = jax.nn.silu(x_c)

    dt, Bm, Cm = _ssm_params(p, cfg, x_c)
    A = -jnp.exp(p["A_log"])  # (di, n)
    h0 = (
        jnp.zeros((x.shape[0], di, cfg.ssm.d_state), jnp.float32)
        if state is None
        else state["h"].astype(jnp.float32)
    )
    xcf = x_c.astype(jnp.float32)

    def one(h, x_t, dt_t, B_t, C_t):
        x_t = x_t.astype(jnp.float32)
        dt_t = dt_t.astype(jnp.float32)
        B_t = B_t.astype(jnp.float32)
        C_t = C_t.astype(jnp.float32)
        decay = jnp.exp(dt_t[..., None] * A[None])  # (B,di,n)
        h = decay * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        return h, jnp.einsum("bdn,bn->bd", h, C_t)

    # stream-dtype option: x/B/C streams may be stored bf16 (they carry the
    # model's native activation precision); dt stays f32 — its error
    # compounds through exp(dt*A) decay products over the whole sequence.
    sdt = jnp.dtype(cfg.ssm.stream_dtype)
    xcf, Bm, Cm = (t.astype(sdt) for t in (xcf, Bm, Cm))
    T = x.shape[1]
    u = cfg.ssm.scan_unroll if (cfg.ssm.scan_unroll > 1
                                and T % cfg.ssm.scan_unroll == 0) else 1
    if u == 1:
        def step(h, xs):
            x_t, dt_t, B_t, C_t = xs
            return one(h, x_t, dt_t, B_t, C_t)

        xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xcf, dt, Bm, Cm))
        h_fin, ys = jax.lax.scan(step, h0, xs)
        y = jnp.moveaxis(ys, 0, 1) + p["D"] * xcf  # (B,T,di)
    else:
        # unrolled chunks: the carry stays on-chip for u steps per scan
        # iteration -> ~u x less HBM state traffic (see SSMConfig.scan_unroll)
        def chunk(h, xs):
            xc, dtc, Bc, Cc = xs  # (u, B, ...)
            ys = []
            for i in range(u):
                h, y_t = one(h, xc[i], dtc[i], Bc[i], Cc[i])
                ys.append(y_t)
            return h, jnp.stack(ys)

        def chunkify(t):
            tt = jnp.moveaxis(t, 1, 0)  # (T, B, ...)
            return tt.reshape(T // u, u, *tt.shape[1:])

        xs = tuple(chunkify(t) for t in (xcf, dt, Bm, Cm))
        h_fin, ys = jax.lax.scan(chunk, h0, xs)
        y = jnp.moveaxis(ys.reshape(T, *ys.shape[2:]), 0, 1) + p["D"] * xcf
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return y, {"h": h_fin, "conv": new_buf}


def init_state(cfg, B: int) -> dict:
    return {
        "h": jnp.zeros((B, d_inner(cfg), cfg.ssm.d_state), jnp.float32),
        "conv": jnp.zeros((B, cfg.ssm.d_conv - 1, d_inner(cfg)), cfg.jdtype),
    }
