"""Minimal pure-JAX optimizers (no optax in the container).

An optimizer is (init_fn, update_fn):
  state = init(params)
  new_params, new_state = update(params, grads, state, lr)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(params, grads, state, lr):
        new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads,
        )
        return new, state

    return Optimizer(init, update)


def momentum(beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(params, grads, state, lr):
        vel = jax.tree.map(lambda v, g: beta * v + g.astype(jnp.float32), state, grads)
        new = jax.tree.map(
            lambda p, v: (p.astype(jnp.float32) - lr * v).astype(p.dtype), params, vel
        )
        return new, vel

    return Optimizer(init, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.copy, z), "t": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new = jax.tree.map(
            lambda p, mm, vv: (
                p.astype(jnp.float32) - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
            ).astype(p.dtype),
            params, m, v,
        )
        return new, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def exponential_decay(base_lr: float, rate: float) -> Callable:
    """Paper's schedule: lr * rate^round (0.995 per communication round)."""

    def schedule(round_idx):
        return base_lr * rate ** jnp.asarray(round_idx, jnp.float32)

    return schedule


OPTIMIZERS = {"sgd": sgd, "momentum": momentum, "adam": adam}
