"""Pytree checkpointing: nested-dict trees <-> single .npz files, plus a
checkpoint-directory layer (atomic write-then-rename, a `latest` pointer,
retention) for kill/resume of a running scan.

Paths are flattened with '/' separators; tuples/namedtuples are converted
to dicts by the caller — `core.fl.state_to_tree` / `state_from_tree` are
the RoundState codec (they replaced the pre-RoundState server-state hook
in the PR 5 refactor). Leaf encodings that numpy cannot round-trip
natively get a name tag:

* bfloat16         -> uint16 view, name suffixed ``__bf16__``
* typed PRNG keys  -> `jax.random.key_data` uint32 payload, name suffixed
                      ``__key:<impl>__`` (restored via `wrap_key_data`);
                      untagged uint32 arrays load back as plain arrays —
                      the old-style raw-key fallback is applied by
                      `core.fl.state_from_tree`, not here.
* None leaves      -> zero-byte sentinel named ``<path>__none__`` (an
                      optional RoundState field that is off must survive
                      a round trip as None, not vanish)
* empty dicts      -> zero-byte sentinel named ``<path>__empty__``

Dict keys containing the ``/`` separator are rejected with a clear error
instead of silently corrupting the flattened paths.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_BF16_TAG = "__bf16__"
_NONE_TAG = "__none__"
_EMPTY_TAG = "__empty__"
_KEY_TAG_RE = re.compile(r"__key:([A-Za-z0-9_-]+)__$")

_CKPT_RE = re.compile(r"^ckpt_(\d{8})\.npz$")
_LATEST = "latest"


def _is_typed_key(x) -> bool:
    dt = getattr(x, "dtype", None)
    return dt is not None and jax.dtypes.issubdtype(dt, jax.dtypes.prng_key)


def _flatten(tree: PyTree, prefix: str = "") -> dict:
    out = {}
    if isinstance(tree, dict):
        if not tree and prefix:
            out[prefix[:-1] + _EMPTY_TAG] = np.zeros((0,), np.uint8)
            return out
        for k, v in tree.items():
            if "/" in str(k):
                raise ValueError(
                    f"checkpoint path component {k!r} (under "
                    f"{prefix!r}) contains the '/' separator — it would "
                    "corrupt the flattened key; rename the field")
            out.update(_flatten(v, f"{prefix}{k}/"))
        return out
    key = prefix[:-1]
    if tree is None:
        out[key + _NONE_TAG] = np.zeros((0,), np.uint8)
        return out
    if _is_typed_key(tree):
        impl = str(jax.random.key_impl(tree))
        out[f"{key}__key:{impl}__"] = np.asarray(jax.random.key_data(tree))
        return out
    arr = np.asarray(tree)
    if arr.dtype == jnp.bfloat16:
        out[key + _BF16_TAG] = arr.view(np.uint16)
    else:
        out[key] = arr
    return out


def _unflatten(flat: dict) -> PyTree:
    tree: dict = {}
    for key, arr in flat.items():
        value: Any
        m = _KEY_TAG_RE.search(key)
        if m is not None:
            key = key[: m.start()]
            value = jax.random.wrap_key_data(
                jnp.asarray(arr, jnp.uint32), impl=m.group(1))
        elif key.endswith(_NONE_TAG):
            key = key[: -len(_NONE_TAG)]
            value = None
        elif key.endswith(_EMPTY_TAG):
            key = key[: -len(_EMPTY_TAG)]
            value = {}
        elif key.endswith(_BF16_TAG):
            key = key[: -len(_BF16_TAG)]
            value = jnp.asarray(arr.view(jnp.bfloat16))
        else:
            value = jnp.asarray(arr)
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    return tree


def _norm_path(path: str) -> str:
    """np.savez appends '.npz' when the name lacks it; normalize BOTH
    save and load onto the suffixed name so `load(p)` always finds what
    `save(p)` wrote."""
    return path if path.endswith(".npz") else path + ".npz"


def save(path: str, tree: PyTree) -> str:
    """Atomically write `tree` to `path` (suffix-normalized to .npz).

    The archive is written to a sibling temp file and `os.replace`d into
    place, so a writer killed mid-save never leaves a torn checkpoint
    under the final name. Returns the normalized path."""
    path = _norm_path(path)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return path


def load(path: str) -> PyTree:
    with np.load(_norm_path(path)) as z:
        return _unflatten({k: z[k] for k in z.files})


# ------------------------------------------------ checkpoint directories


def checkpoint_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")


def list_checkpoints(ckpt_dir: str) -> "list[tuple[int, str]]":
    """(step, path) pairs found in `ckpt_dir`, ascending by step."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _CKPT_RE.match(name)
        if m:
            out.append((int(m.group(1)), os.path.join(ckpt_dir, name)))
    return sorted(out)


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree,
                    keep: int = 3) -> str:
    """Durable snapshot at `step`: atomic archive write, then the
    `latest` pointer is atomically swung to it, then retention deletes
    all but the newest `keep` archives (the pointer target is always
    among the survivors). Returns the archive path."""
    path = save(checkpoint_path(ckpt_dir, step), tree)
    tmp = os.path.join(ckpt_dir, f"{_LATEST}.tmp.{os.getpid()}")
    with open(tmp, "w") as f:
        f.write(os.path.basename(path) + "\n")
    os.replace(tmp, os.path.join(ckpt_dir, _LATEST))
    if keep > 0:
        for _, old in list_checkpoints(ckpt_dir)[:-keep]:
            if os.path.abspath(old) != os.path.abspath(path):
                os.remove(old)
    return path


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    """Path of the newest complete checkpoint, or None.

    Trusts the `latest` pointer when it resolves; falls back to the
    highest-step archive on disk (a crash can kill the writer between
    the archive rename and the pointer swing)."""
    ptr = os.path.join(ckpt_dir, _LATEST)
    if os.path.isfile(ptr):
        with open(ptr) as f:
            cand = os.path.join(ckpt_dir, f.read().strip())
        if os.path.isfile(cand):
            return cand
    ckpts = list_checkpoints(ckpt_dir)
    return ckpts[-1][1] if ckpts else None


def load_latest(ckpt_dir: str) -> "Optional[tuple[int, PyTree]]":
    """(step, tree) of the newest checkpoint in `ckpt_dir`, or None."""
    path = latest_checkpoint(ckpt_dir)
    if path is None:
        return None
    step = int(_CKPT_RE.match(os.path.basename(path)).group(1))
    return step, load(path)
