"""Pytree checkpointing: nested-dict trees <-> a single .npz file.

Paths are flattened with '/' separators; tuples/namedtuples are converted
to dicts by the caller (see core.server.ServerState.to_tree). Arrays are
stored as numpy; bfloat16 round-trips via a uint16 view with a dtype tag.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_BF16_TAG = "__bf16__"


def _flatten(tree: PyTree, prefix: str = "") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
        return out
    key = prefix[:-1]
    arr = np.asarray(tree)
    if arr.dtype == jnp.bfloat16:
        out[key + _BF16_TAG] = arr.view(np.uint16)
    else:
        out[key] = arr
    return out


def _unflatten(flat: dict) -> PyTree:
    tree: dict = {}
    for key, arr in flat.items():
        if key.endswith(_BF16_TAG):
            key = key[: -len(_BF16_TAG)]
            arr = arr.view(jnp.bfloat16)
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(arr)
    return tree


def save(path: str, tree: PyTree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    host = jax.tree.map(np.asarray, tree)
    np.savez(path, **_flatten(host))


def load(path: str) -> PyTree:
    with np.load(path) as z:
        return _unflatten({k: z[k] for k in z.files})
