"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — device count is locked at first jax init,
and only launch/dryrun.py forces the 512-host-device placeholder.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod mesh: 16x16 (data, model) per pod; 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (tests/benches see 1 device)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants for the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
