"""HLO text analysis: collective-traffic accounting for the roofline.

`compiled.cost_analysis()` has no collective-bytes entry, so we parse the
(post-SPMD, per-device) HLO and sum the *result* sizes of every collective
op — the standard napkin model for bytes crossing the ICI per device
(all-reduce moves ~2x its size ring-wise; we report the raw result bytes
and note the convention in EXPERIMENTS.md).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective result-byte totals from a (per-device) HLO module.

    Returns {"all-reduce": bytes, ..., "total": bytes, "count": n_ops}.
    '-done' halves of async pairs are skipped to avoid double counting.
    """
    out: dict = defaultdict(int)
    count = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        if f"{m.group(2)}-done(" in line:
            continue
        result_part = m.group(1)
        b = _shape_bytes(result_part)
        out[m.group(2)] += b
        count += 1
    out["total"] = sum(out[c] for c in COLLECTIVES if c in out)
    out["count"] = count
    return dict(out)


def op_histogram(hlo_text: str, ops=("fusion", "dot", "custom-call", "scatter",
                                     "gather", "convolution")) -> dict:
    hist: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        for op in ops:
            if f" = {op}(" in line or re.search(rf"=\s*[a-z0-9\[\],{{}} ]*\s{op}\(", line):
                hist[op] += 1
    return dict(hist)
