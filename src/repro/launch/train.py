"""Production federated-training launcher.

On a real TPU pod this runs the same compiled round the dry-run lowers,
over the production mesh; on this CPU container use --host-mesh with a
reduced (smoke) arch to execute end-to-end.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --host-mesh \
      --smoke --rounds 5
  # pod usage (unchanged code path):
  python -m repro.launch.train --arch gemma-2b --rounds 1000 [--multi-pod]
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--method", choices=["fedadp", "fedavg"], default="fedadp")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--host-mesh", action="store_true",
                    help="1-device mesh (CPU execution)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--stale", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import registry, shapes as shapes_mod
    from repro.core import fl as fl_mod
    from repro.data import synthetic
    from repro.launch import steps
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.models import transformer

    name = args.arch + ("-smoke" if args.smoke else "")
    cfg = registry.get(name)
    mesh = make_host_mesh() if args.host_mesh else make_production_mesh(
        multi_pod=args.multi_pod)
    shape = shapes_mod.SHAPES["train_4k"]
    if args.seq or args.global_batch:
        shape = dataclasses.replace(
            shape, seq_len=args.seq or shape.seq_len,
            global_batch=args.global_batch or shape.global_batch,
        )

    fn, sds, in_shard, out_shard, meta = steps.build_train_step(
        cfg, mesh, shape, method=args.method, stale=args.stale,
        local_steps=args.tau,
    )
    K, B, tau = meta["K"], meta["B"], meta["tau"]
    print(f"arch={cfg.name} mode={meta['fl_mode']} K={K} B={B} tau={tau} "
          f"T={shape.seq_len} mesh={dict(mesh.shape)}")

    with mesh:
        step = jax.jit(fn, in_shardings=in_shard, out_shardings=out_shard)
        # the exact config build_train_step lowered with — RoundState's
        # pytree structure is a function of it, so a hand-rebuilt copy
        # could silently diverge from the compiled signature
        flcfg = fl_mod.FLConfig(**meta["flcfg"])
        params = transformer.init_params(jax.random.key(0), cfg)
        state = fl_mod.init_round_state(flcfg, params)
        state = jax.device_put(state, in_shard[0])
        sel = jnp.arange(K, dtype=jnp.int32)
        sizes = jnp.ones((K,))
        for r in range(args.rounds):
            toks = synthetic.lm_token_batches(
                seed=r, num_clients=K, batch=tau * B, seq=shape.seq_len,
                vocab=cfg.vocab_size,
            ).reshape(K, tau, B, shape.seq_len)
            batch = {"tokens": jnp.asarray(toks)}
            for k2, spec in sds[1].items():
                if k2 != "tokens":
                    batch[k2] = jnp.zeros(spec.shape, spec.dtype)
            t0 = time.time()
            state, m = step(state, batch, sel, sizes)
            print(f"round {r:4d} loss {float(m['loss']):.4f} "
                  f"div {float(m['divergence']):.3f} ({time.time()-t0:.1f}s)")
        if args.ckpt:
            from repro.checkpoint import io as ckpt_io

            ckpt_io.save(args.ckpt, {"params": state.params})
            print("checkpoint ->", args.ckpt)


if __name__ == "__main__":
    main()
