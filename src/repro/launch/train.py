"""Production federated-training launcher.

On a real TPU pod this runs the same compiled round the dry-run lowers,
over the production mesh; on this CPU container use --host-mesh with a
reduced (smoke) arch to execute end-to-end.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --host-mesh \
      --smoke --rounds 5
  # pod usage (unchanged code path):
  python -m repro.launch.train --arch gemma-2b --rounds 1000 [--multi-pod]
  # preemptible runs: --ckpt DIR [--ckpt-every N] snapshots the FULL
  # RoundState (params, angles, EF, RNG, round); --resume continues
  # bit-exactly from the latest snapshot:
  python -m repro.launch.train --arch gemma-2b --rounds 1000 \
      --ckpt /ckpts/run1 --ckpt-every 50 --resume
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--method", choices=["fedadp", "fedavg"], default="fedadp")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--host-mesh", action="store_true",
                    help="1-device mesh (CPU execution)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--stale", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint DIRECTORY: the full RoundState is "
                         "snapshotted there (atomic, `latest` pointer)")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="also checkpoint every N rounds (0: only at end)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --ckpt; "
                         "training continues bit-exactly at the saved "
                         "round (--rounds is the TOTAL round budget)")
    ap.add_argument("--telemetry", default=None, metavar="DIR",
                    help="stream round/node/span telemetry to "
                         "DIR/telemetry.jsonl (repro.telemetry JSONL "
                         "schema; summarize with scripts/flstat.py). "
                         "Builds the step with FLConfig(telemetry='node') "
                         "— omit for the telemetry-free jaxpr")
    ap.add_argument("--telemetry-every", type=int, default=1, metavar="N",
                    help="emit round/node events only every N rounds "
                         "(spans and manifest always emit)")
    args = ap.parse_args()
    if args.resume and not args.ckpt:
        ap.error("--resume needs --ckpt (the directory to resume from)")
    if args.telemetry_every < 1:
        ap.error("--telemetry-every must be >= 1")

    import dataclasses
    import hashlib

    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro
    from repro.configs import registry, shapes as shapes_mod
    from repro.data import synthetic
    from repro.launch import steps
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.models import transformer

    name = args.arch + ("-smoke" if args.smoke else "")
    cfg = registry.get(name)
    mesh = make_host_mesh() if args.host_mesh else make_production_mesh(
        multi_pod=args.multi_pod)
    shape = shapes_mod.SHAPES["train_4k"]
    if args.seq or args.global_batch:
        shape = dataclasses.replace(
            shape, seq_len=args.seq or shape.seq_len,
            global_batch=args.global_batch or shape.global_batch,
        )

    fn, sds, in_shard, out_shard, meta = steps.build_train_step(
        cfg, mesh, shape, method=args.method, stale=args.stale,
        local_steps=args.tau,
        telemetry="node" if args.telemetry else None,
    )
    K, B, tau = meta["K"], meta["B"], meta["tau"]
    print(f"arch={cfg.name} mode={meta['fl_mode']} K={K} B={B} tau={tau} "
          f"T={shape.seq_len} mesh={dict(mesh.shape)}")

    from repro.checkpoint import io as ckpt_io
    from repro.telemetry import report as tel_report
    from repro.telemetry import sinks as tel_sinks
    from repro.telemetry import spans as tel_spans

    sink = None
    spans = tel_spans.SpanTimer()
    if args.telemetry:
        import os

        sink = tel_sinks.JSONLSink(os.path.join(args.telemetry,
                                                "telemetry.jsonl"))
        spans = tel_spans.SpanTimer(sink)

    with mesh:
        step = jax.jit(fn, in_shardings=in_shard, out_shardings=out_shard)
        # the exact config build_train_step lowered with — RoundState's
        # pytree structure is a function of it, so a hand-rebuilt copy
        # could silently diverge from the compiled signature
        flcfg = repro.FLConfig(**meta["flcfg"])
        start = 0
        if args.resume:
            loaded = ckpt_io.load_latest(args.ckpt)
            if loaded is None:
                raise SystemExit(f"--resume: no checkpoint in {args.ckpt}")
            step_no, tree = loaded
            state = repro.state_from_tree(flcfg, tree)
            start = int(state.round)
            print(f"resumed {args.ckpt} @ round {start} (ckpt_{step_no:08d})")
        else:
            params = transformer.init_params(jax.random.key(0), cfg)
            state = repro.init_round_state(flcfg, params)
        state = jax.device_put(state, in_shard[0])
        sel = jnp.arange(K, dtype=jnp.int32)
        sizes = jnp.ones((K,))
        if sink is not None:
            tel_sinks.emit_manifest(sink, flcfg,
                                    extra={"arch": cfg.name,
                                           "mesh": dict(mesh.shape),
                                           "start_round": start})

        def checkpoint(round_no: int) -> None:
            with spans.span("checkpoint", round=round_no):
                ckpt_io.save_checkpoint(args.ckpt, round_no,
                                        repro.state_to_tree(state))
            print(f"checkpoint -> {args.ckpt} @ round {round_no}")

        for r in range(start, args.rounds):
            # round-seeded synthetic batches: the stream a resumed run
            # sees at round r is identical to the uninterrupted run's
            toks = synthetic.lm_token_batches(
                seed=r, num_clients=K, batch=tau * B, seq=shape.seq_len,
                vocab=cfg.vocab_size,
            ).reshape(K, tau, B, shape.seq_len)
            batch = {"tokens": jnp.asarray(toks)}
            for k2, spec in sds[1].items():
                if k2 != "tokens":
                    batch[k2] = jnp.zeros(spec.shape, spec.dtype)
            t0 = time.time()
            with spans.span("round", round=r + 1):
                state, m = step(state, batch, sel, sizes)
                m = jax.device_get(m)
            print(f"round {r:4d} loss {float(m['loss']):.4f} "
                  f"div {float(m['divergence']):.3f} ({time.time()-t0:.1f}s)")
            if sink is not None:
                tel_sinks.emit_round_block(sink, m, r,
                                           every=args.telemetry_every)
            if (args.ckpt and args.ckpt_every
                    and (r + 1) % args.ckpt_every == 0):
                checkpoint(r + 1)
        if args.ckpt:
            checkpoint(int(jax.device_get(state.round)))
        h = hashlib.sha256()
        for leaf in jax.tree.leaves(jax.device_get(state.params)):
            h.update(np.ascontiguousarray(leaf).tobytes())
        print("params_sha256", h.hexdigest())
        if sink is not None:
            tel_sinks.emit_summary(sink, rounds=args.rounds - start)
            sink.close()
            s = tel_report.summarize(tel_sinks.load_events(sink.path))
            print(f"telemetry -> {sink.path}")
            print(tel_report.oneline(s))


if __name__ == "__main__":
    main()
