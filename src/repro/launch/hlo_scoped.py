"""Loop-aware HLO cost analysis.

XLA's `compiled.cost_analysis()` counts a while-loop body ONCE (verified:
a 10-step scanned matmul reports 1/10th the unrolled FLOPs), so any model
lowered as `lax.scan` over layers/clients is massively under-counted. This
module re-derives the three roofline quantities by walking the optimized
per-device HLO text with loop-trip multipliers:

  * flops        — 2*M*N*K per dot (from operand/result shapes), scaled by
                   the product of enclosing while-loop trip counts;
  * hbm_bytes    — sum over fusion/standalone op boundaries of operand +
                   result bytes (fusion internals live in VMEM/registers,
                   so fusion boundaries model HBM traffic on TPU);
  * collectives  — result bytes per collective op, trip-scaled.

Trip counts come from the canonical counted-loop pattern XLA emits for
scans: the condition computation compares the induction variable with an
integer constant. Loops whose trip count cannot be inferred get
multiplier 1 and are reported in `unknown_trip_loops`.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# computation headers have nested parens in the param list, so only anchor
# on "name (" ... "{" at end of line
_COMP_HDR = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"([\w\-]+)\((.*)$"
)
_OPERAND = re.compile(r"%([\w.\-]+)")
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                        r"(?:%([\w.\-]+)|\{([^}]*)\})")

COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}


def _shape_list(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_TOKEN.findall(type_str):
        if dt in _DTYPE_BYTES:
            shape = tuple(int(d) for d in dims.split(",")) if dims else ()
            out.append((dt, shape))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, shape in _shape_list(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


class _Op:
    __slots__ = ("name", "type_str", "opcode", "rest", "operands", "calls")

    def __init__(self, name, type_str, opcode, rest):
        self.name = name
        self.type_str = type_str
        self.opcode = opcode
        self.rest = rest
        self.operands = []
        self.calls = []


def parse_module(text: str) -> dict:
    """-> {comp_name: [Op]}; first ENTRY computation under key '__entry__'."""
    comps: dict = {}
    entry = None
    cur = None
    for line in text.splitlines():
        if line.rstrip().endswith("{") and "(" in line and "=" not in line.split("(")[0]:
            m = _COMP_HDR.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        op = _Op(m.group(1), m.group(2), m.group(3), m.group(4))
        # operand list: up to first "), " attribute break
        paren = m.group(4)
        depth = 1
        end = len(paren)
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        op.operands = _OPERAND.findall(paren[:end])
        for g1, g2 in _CALL_ATTR.findall(line):
            if g1:
                op.calls.append(g1)
            elif g2:
                op.calls.extend(_OPERAND.findall(g2))
        comps[cur].append(op)
    comps["__entry__"] = entry
    return comps


def _dot_flops(op: _Op, shapes: dict) -> float:
    """2 * prod(result dims) * contraction size for dot ops."""
    res = _shape_list(op.type_str)
    if not res:
        return 0.0
    out_n = 1
    for d in res[0][1]:
        out_n *= d
    # contraction size: lhs elements / (batch+free dims present in result)
    lhs = shapes.get(op.operands[0]) if op.operands else None
    if lhs is None:
        return 0.0
    lhs_n = 1
    for d in lhs:
        lhs_n *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    if not m:
        return 2.0 * out_n  # unknown — lower bound
    k = 1
    for d in m.group(1).split(","):
        if d:
            k *= lhs[int(d)]
    return 2.0 * out_n * k


def _trip_count(comps: dict, cond_name: str) -> int | None:
    """Trip count from a counted-loop condition.

    XLA often wraps the compare in a kLoop fusion, so the robust signal is
    the integer constant living in the condition computation itself (scan
    emits exactly one: the trip bound). Falls back to constants in called
    computations."""
    def int_consts(name):
        out = []
        for op in comps.get(name, []):
            if op.opcode == "constant" and op.type_str.startswith("s"):
                m = re.match(r"\s*(-?\d+)\s*\)", op.rest)
                if m:
                    out.append(int(m.group(1)))
        return out

    consts = int_consts(cond_name)
    if not consts:
        for op in comps.get(cond_name, []):
            for c in op.calls:
                consts += int_consts(c)
    pos = [c for c in consts if c > 0]
    return max(pos) if pos else None


def analyze(text: str) -> dict:
    """Loop-aware (flops, hbm_bytes, collective bytes) for one HLO module."""
    comps = parse_module(text)
    entry = comps.pop("__entry__")
    shapes: dict = {}
    for ops in comps.values():
        for op in ops:
            res = _shape_list(op.type_str)
            shapes[op.name] = res[0][1] if len(res) == 1 else None
            if op.opcode == "parameter":
                shapes[op.name] = res[0][1] if res else None

    unknown_loops = []
    memo: dict = {}

    def cost_of(comp: str, depth: int = 0) -> dict:
        if comp in memo:
            return memo[comp]
        if depth > 64 or comp not in comps:
            return {"flops": 0.0, "hbm": 0.0, "coll": defaultdict(float), "coll_n": 0}
        total = {"flops": 0.0, "hbm": 0.0, "coll": defaultdict(float), "coll_n": 0}
        for op in comps[comp]:
            oc = op.opcode
            if oc == "while":
                body, cond = None, None
                m = re.search(r"body=%?([\w.\-]+)", op.rest)
                if m:
                    body = m.group(1)
                m = re.search(r"condition=%?([\w.\-]+)", op.rest)
                if m:
                    cond = m.group(1)
                trips = _trip_count(comps, cond) if cond else None
                if trips is None:
                    trips = 1
                    unknown_loops.append(op.name)
                sub = cost_of(body, depth + 1) if body else None
                if sub:
                    total["flops"] += trips * sub["flops"]
                    total["hbm"] += trips * sub["hbm"]
                    total["coll_n"] += trips * sub["coll_n"]
                    for k, v in sub["coll"].items():
                        total["coll"][k] += trips * v
                continue
            if oc in ("call", "conditional", "async-start"):
                for c in op.calls:
                    sub = cost_of(c, depth + 1)
                    total["flops"] += sub["flops"]
                    total["hbm"] += sub["hbm"]
                    total["coll_n"] += sub["coll_n"]
                    for k, v in sub["coll"].items():
                        total["coll"][k] += v
                continue
            base = oc.replace("-start", "")
            if base in ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"):
                if oc.endswith("-done"):
                    continue
                total["coll"][base] += _nbytes(op.type_str)
                total["coll_n"] += 1
                total["hbm"] += _nbytes(op.type_str)
                continue
            if oc == "fusion":
                # fusion boundary = HBM traffic; count dots inside the fused
                # computation for flops
                total["hbm"] += _nbytes(op.type_str)
                for o in op.operands:
                    if o in shapes and shapes[o] is not None:
                        n = 1
                        for d in shapes[o]:
                            n *= d
                        # dtype unknown from operand name; approximate via
                        # the def's type string when available
                total["hbm"] += sum(
                    _op_bytes_by_name(comps, comp, o, shapes) for o in op.operands
                )
                for c in op.calls:
                    sub = cost_of(c, depth + 1)
                    total["flops"] += sub["flops"]
                continue
            if oc in ("dot", "convolution"):
                total["flops"] += _dot_flops(op, shapes)
                total["hbm"] += _nbytes(op.type_str)
                total["hbm"] += sum(
                    _op_bytes_by_name(comps, comp, o, shapes) for o in op.operands
                )
                continue
            if oc in ("copy", "copy-start", "transpose", "reshape", "bitcast",
                      "parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast-convert"):
                continue
            # other standalone ops at computation scope: count result bytes
            total["hbm"] += _nbytes(op.type_str)
        memo[comp] = total
        return total

    _type_cache.clear()
    out = cost_of(entry) if entry else {"flops": 0.0, "hbm": 0.0,
                                        "coll": defaultdict(float), "coll_n": 0}
    coll = dict(out["coll"])
    coll["total"] = sum(coll.values())
    coll["count"] = out["coll_n"]
    return {
        "flops": out["flops"],
        "hbm_bytes": out["hbm"],
        "collectives": coll,
        "unknown_trip_loops": len(unknown_loops),
    }


_type_cache: dict = {}


def _op_bytes_by_name(comps, comp, name, shapes) -> int:
    key = (comp, name)
    if key in _type_cache:
        return _type_cache[key]
    b = 0
    for op in comps.get(comp, []):
        if op.name == name:
            b = _nbytes(op.type_str)
            break
    _type_cache[key] = b
    return b
