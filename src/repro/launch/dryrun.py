import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (arch x shape x mesh) combo.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices. Smoke
tests and benches do NOT import this module — they see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json

Per combo this prints/records compiled.memory_analysis() (fits-per-device
proof), compiled.cost_analysis() (FLOPs/bytes for the roofline), and the
collective-bytes histogram parsed from the per-device HLO.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import shapes as shapes_mod  # noqa: E402
from repro.configs.registry import ARCHS  # noqa: E402
from repro.launch import hlo as hlo_mod  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            verbose: bool = True, tag: str = "baseline", **kw) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, args, in_shard, out_shard, meta = steps.build_step(arch, shape_name, mesh, **kw)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_shard,
                          out_shardings=out_shard).lower(*args)
        compiled = lowered.compile()
    t1 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax wraps it per-device
        cost = cost[0] if cost else {}
    text = compiled.as_text()
    coll = hlo_mod.collective_bytes(text)
    # loop-aware per-device analysis (XLA cost_analysis counts while bodies
    # once — see hlo_scoped docstring)
    from repro.launch import hlo_scoped

    scoped = hlo_scoped.analyze(text)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "tag": tag,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": int(mesh.devices.size),
        "meta": meta,
        "compile_s": round(t1 - t0, 1),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        },
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "collectives": coll,
        "scoped": {
            "flops": scoped["flops"],
            "hbm_bytes": scoped["hbm_bytes"],
            "collectives": scoped["collectives"],
            "unknown_trip_loops": scoped["unknown_trip_loops"],
        },
    }
    if verbose:
        m = rec["memory"]
        per_dev = (m["argument_bytes"] + m["output_bytes"] + m["temp_bytes"]
                   - m["alias_bytes"])
        print(f"[{arch} x {shape_name} x {rec['mesh']}] compile {rec['compile_s']}s")
        print(f"  memory_analysis: args={m['argument_bytes']/2**30:.2f}GiB "
              f"out={m['output_bytes']/2**30:.2f}GiB temp={m['temp_bytes']/2**30:.2f}GiB "
              f"(~{per_dev/2**30:.2f}GiB/device live)")
        print(f"  cost_analysis: flops={rec['flops']:.3e} "
              f"bytes={rec['bytes_accessed']:.3e} (loop bodies counted once)")
        s = rec["scoped"]
        print(f"  scoped (loop-aware): flops={s['flops']:.3e} "
              f"hbm={s['hbm_bytes']:.3e} "
              f"coll={s['collectives'].get('total', 0)/2**20:.1f}MiB "
              f"unknown_loops={s['unknown_trip_loops']}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(shapes_mod.SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="sweep all arch x shape")
    ap.add_argument("--out", default=None, help="append JSON records here")
    ap.add_argument("--method", default="fedadp", choices=["fedadp", "fedavg"])
    ap.add_argument("--stale", action="store_true",
                    help="sequential engine: one-pass stale angles")
    ap.add_argument("--q-chunk", type=int, default=0,
                    help="query-blocked attention chunk (perf iterations)")
    ap.add_argument("--mqa-replicate-kv", action="store_true",
                    help="replicate k/v projections when kv_heads < model axis")
    ap.add_argument("--ssm-unroll", type=int, default=0,
                    help="mamba scan unroll factor (perf iterations)")
    ap.add_argument("--loss-chunk", type=int, default=0,
                    help="chunked unembed+CE over tokens (perf iterations)")
    ap.add_argument("--rs-grads", action="store_true",
                    help="sequential: constrain grads to FSDP spec (RS not AR)")
    ap.add_argument("--ssm-stream-bf16", action="store_true",
                    help="mamba scan xs streams in bf16 (perf iterations)")
    ap.add_argument("--act-constrain", action="store_true",
                    help="in-model activation sharding constraints")
    ap.add_argument("--moe-combine-bf16", action="store_true",
                    help="MoE combine-scatter accumulates in bf16")
    ap.add_argument("--angle-filter", default="all", choices=["all", "dense_only"])
    ap.add_argument("--tag", default="baseline",
                    help="record tag for perf-iteration bookkeeping")
    args = ap.parse_args()

    combos = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(shapes_mod.SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                combos.append((a, s, m))

    done = set()
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                if line.strip():
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"],
                              r.get("tag", "baseline")))
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)

    records, failures = [], []
    for a, s, m in combos:
        mesh_name = "2x16x16" if m else "16x16"
        if (a, s, mesh_name, args.tag) in done:
            print(f"[skip cached] {a} x {s} x {mesh_name}", flush=True)
            continue
        try:
            kw = {}
            if shapes_mod.SHAPES[s].kind == "train":
                kw = {"method": args.method, "stale": args.stale,
                      "angle_filter": args.angle_filter}
                if args.mqa_replicate_kv:
                    kw["mqa_replicate_kv"] = True
                if args.ssm_unroll:
                    kw["ssm_unroll"] = args.ssm_unroll
                if args.loss_chunk:
                    kw["loss_chunk"] = args.loss_chunk
                if args.rs_grads:
                    kw["rs_grads"] = True
                if args.ssm_stream_bf16:
                    kw["ssm_stream_bf16"] = True
                if args.act_constrain:
                    kw["act_constrain"] = True
                if args.moe_combine_bf16:
                    kw["moe_combine_bf16"] = True
            if args.q_chunk and shapes_mod.SHAPES[s].kind != "decode":
                kw["q_chunk"] = args.q_chunk
            rec = run_one(a, s, multi_pod=m, tag=args.tag, **kw)
            records.append(rec)
            if args.out:  # stream: every record lands immediately
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
        except Exception as e:  # noqa: BLE001 — sweep must report all failures
            traceback.print_exc()
            failures.append({"arch": a, "shape": s, "multi_pod": m, "error": str(e)})
        import sys
        sys.stdout.flush()
    print(f"\ndry-run: {len(records)} ok, {len(failures)} failed")
    for f in failures:
        print("  FAIL", f)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
