"""Production serving launcher: prefill + continuous batched decode.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --host-mesh \
      --smoke --steps 16
  # pod usage: python -m repro.launch.serve --arch deepseek-v2-236b --shape decode_32k
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--host-mesh", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    args = ap.parse_args()

    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import registry, shapes as shapes_mod
    from repro.launch import steps
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.models import transformer

    name = args.arch + ("-smoke" if args.smoke else "")
    cfg = registry.get(name)
    mesh = make_host_mesh() if args.host_mesh else make_production_mesh()
    shape = shapes_mod.SHAPES[args.shape]
    if args.batch or args.seq:
        shape = dataclasses.replace(
            shape, global_batch=args.batch or shape.global_batch,
            seq_len=args.seq or shape.seq_len,
        )
    cfg2 = shapes_mod.config_for_shape(cfg, shape)

    fn, sds, in_shard, out_shard, meta = steps.build_decode_step(cfg, mesh, shape)
    with mesh:
        step = jax.jit(fn, in_shardings=in_shard, out_shardings=out_shard,
                       donate_argnums=(2,))
        params = transformer.init_params(jax.random.key(0), cfg2)
        params = jax.device_put(params, in_shard[0])
        cache = transformer.init_cache(cfg2, shape.global_batch, shape.seq_len)
        cache = jax.device_put(cache, in_shard[2])
        tok = jnp.zeros((shape.global_batch, 1), jnp.int32)
        pos = shape.seq_len // 2  # mid-cache decode position
        t0 = None
        for i in range(args.steps):
            logits, cache = step(params, tok, cache, jnp.int32(pos + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            if i == 0:
                jax.block_until_ready(tok)
                t0 = time.time()  # exclude compile
        jax.block_until_ready(tok)
        dt = (time.time() - t0) / max(args.steps - 1, 1)
        print(f"[{cfg2.name} x {shape.name}] B={shape.global_batch} "
              f"cache={shape.seq_len}: {dt*1e3:.1f} ms/token (host measure)")


if __name__ == "__main__":
    main()
