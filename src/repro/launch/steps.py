"""Step builders shared by dryrun / train / serve launchers.

Each builder returns (fn, args, in_shardings) where `args` are
jax.ShapeDtypeStruct trees — `jax.jit(fn, in_shardings=...).lower(*args)`
never allocates device memory.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import shapes as shapes_mod
from repro.configs.registry import get as get_arch
from repro.core import fl as fl_mod
from repro.models import sharding, transformer
from repro.models.config import ModelConfig

SEQUENTIAL_THRESHOLD = 40e9  # params; larger models use the sequential engine


def fl_mode_for(cfg: ModelConfig) -> str:
    return "sequential" if cfg.param_count() > SEQUENTIAL_THRESHOLD else "parallel"


def _replicate_extra(cfg: ModelConfig, mesh: Mesh, mqa_replicate_kv: bool):
    """KV projections to replicate when heads can't fill the model axis."""
    if mqa_replicate_kv and cfg.num_kv_heads < mesh.shape.get("model", 1):
        return frozenset({"wk", "wv"})
    return frozenset()


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _batch_total(mesh: Mesh) -> int:
    t = 1
    for a in sharding.batch_axes(mesh):
        t *= mesh.shape[a]
    return t


def params_sds(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(transformer.init_params, cfg=cfg),
                          jax.random.key(0))


# ------------------------------------------------------------- train


def build_train_step(cfg: ModelConfig, mesh: Mesh, shape: shapes_mod.InputShape,
                     *, fl_mode: str | None = None, method: str = "fedadp",
                     stale: bool = False, local_steps: int = 1,
                     q_chunk: int = 0, angle_filter: str = "all",
                     mqa_replicate_kv: bool = False,
                     ssm_unroll: int = 0, loss_chunk: int = 0,
                     rs_grads: bool = False, ssm_stream_bf16: bool = False,
                     act_constrain: bool = False, moe_combine_bf16: bool = False,
                     telemetry: str | None = None):
    import dataclasses

    if q_chunk:
        cfg = dataclasses.replace(cfg, q_chunk=q_chunk)
    if loss_chunk:
        cfg = dataclasses.replace(cfg, loss_chunk=loss_chunk)
    if ssm_unroll and cfg.ssm is not None:
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, scan_unroll=ssm_unroll))
    if ssm_stream_bf16 and cfg.ssm is not None:
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, stream_dtype="bfloat16"))
    if act_constrain:
        cfg = dataclasses.replace(cfg, act_constrain=True)
        sharding.set_constraint_mesh(mesh)
    if moe_combine_bf16 and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, combine_dtype="bfloat16"))
    rep_extra = _replicate_extra(cfg, mesh, mqa_replicate_kv)
    fl_mode = fl_mode or fl_mode_for(cfg)
    dtot = _batch_total(mesh)
    K = dtot if fl_mode == "parallel" else 16
    B = max(shape.global_batch // K, 1)
    tau = local_steps

    loss_fn = functools.partial(transformer.loss_fn, cfg=cfg)

    def loss(params, batch):
        return loss_fn(params, batch=batch)

    # telemetry=None keeps the lowered step's jaxpr telemetry-free;
    # "node" adds the per-node tel/* metrics (repro.telemetry) and flows
    # through meta["flcfg"] so runtime state rebuilds see it too.
    flcfg = fl_mod.FLConfig(
        num_clients=K, clients_per_round=K, local_steps=tau, method=method,
        mode=fl_mode, stale_angles=stale, telemetry=telemetry,
    )

    p_sds = params_sds(cfg)
    prev_sds = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p_sds)
    # one RoundState pytree carries the whole server-side round state
    # (params, Eq. 9 angles, prev delta, RNG key, round counter) — its
    # SDS comes from the same init the runtime uses, so the lowered
    # signature can never drift from init_round_state's layout.
    state_sds = jax.eval_shape(
        functools.partial(fl_mod.init_round_state, flcfg), p_sds)
    batch_one = shapes_mod.token_batch_specs(cfg, B, shape.seq_len)
    batch_sds = {
        k: jax.ShapeDtypeStruct((K, tau) + v.shape, v.dtype)
        for k, v in batch_one.items()
    }
    args = (
        state_sds, batch_sds,
        jax.ShapeDtypeStruct((K,), jnp.int32),
        jax.ShapeDtypeStruct((K,), jnp.float32),
    )

    fsdp = fl_mode == "sequential"
    p_shard = sharding.param_shardings(p_sds, mesh, fsdp=fsdp,
                                       replicate_extra=rep_extra)
    prev_shard = sharding.param_shardings(prev_sds, mesh, fsdp=fsdp,
                                          replicate_extra=rep_extra)

    delta_constraint = None
    if fl_mode == "parallel":
        # stacked per-client deltas: client axis on (pod, data), tensor dims
        # on the param's own model-axis spec.
        baxes = sharding.batch_axes(mesh)
        kspec = baxes if len(baxes) > 1 else baxes[0]
        spec_leaves = jax.tree.leaves(
            sharding.param_pspecs(p_sds, mesh, fsdp=False,
                                  replicate_extra=rep_extra),
            is_leaf=lambda x: isinstance(x, P),
        )

        def delta_constraint(deltas):
            leaves, treedef = jax.tree.flatten(deltas)
            out = [
                jax.lax.with_sharding_constraint(
                    d, NamedSharding(mesh, P(kspec, *s))
                )
                for d, s in zip(leaves, spec_leaves)
            ]
            return jax.tree.unflatten(treedef, out)

    angle_pred = (
        fl_mod.moe_dense_only_pred
        if (angle_filter == "dense_only" and cfg.moe is not None)
        else None
    )

    grad_constraint = None
    if fl_mode == "sequential" and rs_grads:
        # pin per-step grads to the FSDP param spec: batch-partial grads are
        # reduce-scattered onto the shard instead of all-reduced in full.
        gspec_leaves = jax.tree.leaves(
            sharding.param_pspecs(p_sds, mesh, fsdp=True,
                                  replicate_extra=rep_extra),
            is_leaf=lambda x: isinstance(x, P),
        )

        def grad_constraint(grads):
            leaves, treedef = jax.tree.flatten(grads)
            out = [
                jax.lax.with_sharding_constraint(g, NamedSharding(mesh, s))
                for g, s in zip(leaves, gspec_leaves)
            ]
            return jax.tree.unflatten(treedef, out)

    round_fn = fl_mod.make_round_fn(loss, flcfg, delta_constraint, angle_pred,
                                    grad_constraint)
    if fl_mode == "parallel":
        b_shard = sharding.shard_batch_dim(mesh, batch_sds, default_dim=0)
    else:
        # K is the scan axis; shard the within-client batch dim instead
        def seq_leaf(name, x):
            if name == "positions":  # (K, tau, 3, B, T) — B at dim 3
                dim = 3
            else:
                dim = 2
            axes = sharding.batch_axes(mesh)
            total = _batch_total(mesh)
            spec = [None] * len(x.shape)
            if x.shape[dim] % total == 0 and x.shape[dim] >= total:
                spec[dim] = axes if len(axes) > 1 else axes[0]
            return NamedSharding(mesh, P(*spec))

        b_shard = {k: seq_leaf(k, v) for k, v in batch_sds.items()}
    rep = lambda t: sharding.replicated(mesh, t)
    state_shard = fl_mod.RoundState(
        params=p_shard, angle=rep(state_sds.angle), prev_delta=prev_shard,
        ef=None, dl_ef=None, bcast=None,
        rng=rep(state_sds.rng), round=rep(state_sds.round))
    in_shard = (state_shard, b_shard, rep(args[2]), rep(args[3]))
    out_sds = jax.eval_shape(round_fn, *args)
    out_shard = (state_shard, rep(out_sds[1]))
    # flcfg determines the RoundState pytree structure, so callers that
    # build a runtime state (launch/train.py) must use THIS config, not a
    # hand-rebuilt copy — ship it in meta (as a JSON-safe dict; dryrun
    # serializes meta into results/)
    meta = {"K": K, "B": B, "tau": tau, "fl_mode": fl_mode,
            "flcfg": dataclasses.asdict(flcfg)}
    return round_fn, args, in_shard, out_shard, meta


# ------------------------------------------------------------ prefill


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: shapes_mod.InputShape,
                       *, fsdp: bool | None = None, q_chunk: int = 0):
    if q_chunk:
        import dataclasses

        cfg = dataclasses.replace(cfg, q_chunk=q_chunk)
    B, T = shape.global_batch, shape.seq_len
    if fsdp is None:
        fsdp = cfg.param_count() > SEQUENTIAL_THRESHOLD

    def prefill_step(params, batch):
        logits, aux, cache = transformer.forward(
            params, cfg, batch, mode="prefill", max_len=T
        )
        return logits[:, -1:], cache

    p_sds = params_sds(cfg)
    batch_sds = shapes_mod.token_batch_specs(cfg, B, T)
    p_shard = sharding.param_shardings(p_sds, mesh, fsdp=fsdp)
    b_shard = sharding.shard_batch_dim(mesh, batch_sds, default_dim=0)
    if "positions" in batch_sds:
        b_shard["positions"] = _pos_shard(mesh, batch_sds["positions"], dim=1)
    out_sds = jax.eval_shape(prefill_step, p_sds, batch_sds)
    out_shard = (
        sharding.shard_batch_dim(mesh, out_sds[0], default_dim=0),
        _cache_shardings(cfg, mesh, out_sds[1]),
    )
    return (prefill_step, (p_sds, batch_sds), (p_shard, b_shard), out_shard,
            {"B": B, "T": T})


def _pos_shard(mesh, x, dim):
    axes = sharding.batch_axes(mesh)
    total = _batch_total(mesh)
    spec = [None] * len(x.shape)
    if x.shape[dim] % total == 0 and x.shape[dim] >= total:
        spec[dim] = axes if len(axes) > 1 else axes[0]
    return NamedSharding(mesh, P(*spec))


# ------------------------------------------------------------- decode


def _cache_shardings(cfg, mesh, cache_sds):
    """Decode-cache rules: batch dim over (pod,data); if B is unshardable
    (long_500k B=1) the sequence dim of attention caches goes on "data";
    SSM inner dims follow their params onto "model"."""
    axes = sharding.batch_axes(mesh)
    total = _batch_total(mesh)
    msize = mesh.shape.get("model", 1)
    baxes = axes if len(axes) > 1 else axes[0]

    def leaf_with_path(path, x):
        keys = tuple(getattr(k, "key", getattr(k, "name", "")) for k in path)
        name = keys[-1]
        nd = len(x.shape)
        spec = [None] * nd
        # dim0 = scan group axis (never sharded); dim1 = batch
        if nd >= 2 and x.shape[1] % total == 0 and x.shape[1] >= total:
            spec[1] = baxes
        elif name in ("k", "v", "ckv", "krope", "cross_k", "cross_v") and nd >= 3:
            if x.shape[2] % mesh.shape.get("data", 1) == 0:
                spec[2] = "data"
        if name in ("k", "v", "cross_k", "cross_v") and nd >= 4:
            if x.shape[3] % msize == 0 and x.shape[3] >= msize:
                spec[3] = "model"
        if name == "h" and nd >= 3 and x.shape[2] % msize == 0:
            spec[2] = "model"
        if name == "conv" and nd >= 4 and x.shape[3] % msize == 0:
            spec[3] = "model"
        if name == "S" and nd >= 3 and x.shape[2] % msize == 0:
            spec[2] = "model"  # rwkv heads
        return NamedSharding(mesh, P(*spec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_sds)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_with_path(p, x) for p, x in flat]
    )


def build_decode_step(cfg: ModelConfig, mesh: Mesh, shape: shapes_mod.InputShape,
                      *, fsdp: bool | None = None):
    cfg = shapes_mod.config_for_shape(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    if fsdp is None:
        fsdp = cfg.param_count() > SEQUENTIAL_THRESHOLD

    def serve_step(params, token, cache, pos):
        return transformer.decode_step(params, cfg, token, cache, pos)

    p_sds = params_sds(cfg)
    d = shapes_mod.decode_specs(cfg, B, S)
    p_shard = sharding.param_shardings(p_sds, mesh, fsdp=fsdp)
    tok_shard = sharding.shard_batch_dim(mesh, d["token"], default_dim=0)
    cache_shard = _cache_shardings(cfg, mesh, d["cache"])
    pos_shard = NamedSharding(mesh, P())
    args = (p_sds, d["token"], d["cache"], d["pos"])
    in_shard = (p_shard, tok_shard, cache_shard, pos_shard)
    out_sds = jax.eval_shape(serve_step, *args)
    out_shard = (
        sharding.shard_batch_dim(mesh, out_sds[0], default_dim=0),
        _cache_shardings(cfg, mesh, out_sds[1]),
    )
    return serve_step, args, in_shard, out_shard, {"B": B, "S": S,
                                                   "window": cfg.sliding_window}


def build_step(arch: str, shape_name: str, mesh: Mesh, **kw):
    cfg = get_arch(arch)
    shape = shapes_mod.SHAPES[shape_name]
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape, **kw)
    return build_decode_step(cfg, mesh, shape, **kw)
