"""Device-resident report buffer for the buffered-async aggregation server.

The synchronous round is lockstep: every selected node reports before the
server re-weights by gradient angle. `FLConfig(aggregation="buffered")`
replaces that with a FedBuff-style admission/flush state machine that
stays entirely on device so the scanned driver can carry it through
`lax.scan`:

* The server keeps K concurrency slots — rows of the existing (K, N)
  uplink buffer plus per-row bookkeeping (`ReportBuffer`, folded into
  `fl.RoundState.buf`). A slot holds at most one in-flight report.
* Every server tick, FREE slots admit a fresh client: the client pulls
  the current broadcast, trains, and its (dequantized) wire delta is
  written into the slot together with a simulated arrival delay drawn
  from the device RNG (`draw_arrivals`) or injected via an explicit
  schedule (`core.server.fixed_arrival_schedule`). A dropout report is
  never admitted — the upload is lost in transit and the slot stays
  free, so liveness never depends on timeouts.
* A report LANDS when its delay expires. The server flushes whenever at
  least `buffer_m` of the in-flight reports have landed: the landed rows
  are aggregated with the staleness-discounted FedAdp weights
  (`weighting.buffered_fedadp_weights`) and applied to the master
  params; non-landed rows stay buffered and their `age` — the number of
  model versions elapsed since their client pulled params — increments.

Everything is mask-based (no data-dependent shapes), so one compiled
step serves every tick and the whole machine composes with `lax.scan`,
checkpointing (`ReportBuffer` round-trips through the RoundState codec),
and all three parallel engines. With `buffer_m == K` and no
stragglers/dropouts every tick admits, lands, and flushes the full
cohort at age 0 — bit-for-bit the synchronous round.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ReportBuffer(NamedTuple):
    """Per-slot state of the buffered server's in-flight reports.

    One row per concurrency slot (K = clients_per_round rows). All
    fields are plain arrays so the buffer rides inside `fl.RoundState`
    (scan carry, checkpoint codec) without special casing.
    """

    data: jax.Array  # (K, N) f32 — dequantized report deltas
    slot: jax.Array  # (K,) i32 — population slot of the row's client
    sizes: jax.Array  # (K,) f32 — report data sizes D_i
    age: jax.Array  # (K,) i32 — staleness: model versions since pull
    wait: jax.Array  # (K,) i32 — ticks until the report lands (0 = landed)
    free: jax.Array  # (K,) bool — row is empty (admits next candidate)


def init_report_buffer(k: int, n: int) -> ReportBuffer:
    """An empty K-slot buffer over N-wide report rows (all rows free)."""
    return ReportBuffer(
        data=jnp.zeros((k, n), jnp.float32),
        slot=jnp.zeros((k,), jnp.int32),
        sizes=jnp.ones((k,), jnp.float32),
        age=jnp.zeros((k,), jnp.int32),
        wait=jnp.zeros((k,), jnp.int32),
        free=jnp.ones((k,), bool),
    )


def population_busy(buf: ReportBuffer, num_clients: int) -> jax.Array:
    """(num_clients,) bool — clients with a report in flight.

    A busy client must not be re-selected (its next report would collide
    with the buffered one in the Eq. 9 scatter). Free rows carry stale
    slot ids, so they are routed out of bounds and dropped.
    """
    idx = jnp.where(buf.free, num_clients, buf.slot)
    return (jnp.zeros((num_clients,), bool)
            .at[idx].set(True, mode="drop"))


def draw_arrivals(key, k: int, straggle_prob: float, straggle_max: int,
                  dropout_prob: float):
    """Simulated arrival draw for this tick's K candidate reports.

    Returns (delay, drop): delay is 0 for on-time reports and uniform in
    {1..straggle_max} for stragglers; drop marks reports lost in transit
    (never admitted). Deterministic in `key` — a fixed seed IS a fixed
    straggler/dropout schedule.

    `straggle_max=0` means stragglers are impossible: every report lands
    on time regardless of `straggle_prob` (matching `FLConfig.validate`'s
    contract — it rejects straggle_prob > 0 with straggle_max == 0). The
    key split is unchanged in that case, so the drop stream of a seeded
    run does not depend on whether straggling is enabled.
    """
    kd, ks, ku = jax.random.split(key, 3)
    drop = jax.random.bernoulli(kd, dropout_prob, (k,))
    if straggle_max < 1:
        return jnp.zeros((k,), jnp.int32), drop
    straggle = jax.random.bernoulli(ks, straggle_prob, (k,))
    delay = jax.random.randint(ku, (k,), 1, straggle_max + 1)
    return jnp.where(straggle, delay, 0).astype(jnp.int32), drop


def admit(buf: ReportBuffer, admit_mask: jax.Array, rows: jax.Array,
          sel_idx: jax.Array, data_sizes: jax.Array,
          delay: jax.Array) -> ReportBuffer:
    """Merge this tick's admitted candidate reports into their slots.

    `admit_mask` is (K,) bool — free rows taking a non-busy, non-dropped
    candidate. Occupied rows keep their in-flight report untouched.
    """
    take = admit_mask[:, None]
    return ReportBuffer(
        data=jnp.where(take, rows, buf.data),
        slot=jnp.where(admit_mask, sel_idx.astype(jnp.int32), buf.slot),
        sizes=jnp.where(admit_mask, data_sizes.astype(jnp.float32),
                        buf.sizes),
        age=jnp.where(admit_mask, 0, buf.age),
        wait=jnp.where(admit_mask, delay, buf.wait),
        free=buf.free & ~admit_mask,
    )


def landed_mask(buf: ReportBuffer) -> jax.Array:
    """(K,) bool — occupied rows whose report has arrived at the server."""
    return ~buf.free & (buf.wait <= 0)


def advance(buf: ReportBuffer, landed: jax.Array,
            do_flush: jax.Array) -> ReportBuffer:
    """End-of-tick bookkeeping after the (possible) flush.

    Flushed rows (landed, when `do_flush`) free up; surviving occupied
    rows age by one model version iff a flush advanced the params; and
    in-flight waits tick down toward arrival.
    """
    new_free = buf.free | (landed & do_flush)
    return buf._replace(
        free=new_free,
        age=jnp.where(~new_free & do_flush, buf.age + 1, buf.age),
        wait=jnp.where(new_free, 0, jnp.maximum(buf.wait - 1, 0)),
    )
