"""Federated server loop for the paper's classification experiments.

A thin host-side wrapper over the device-resident driver
(`core.driver`): the node datasets are stacked onto the device once, and
every round — client selection, per-client epoch batching, the compiled
round itself, and the test eval — runs from the device RNG inside one
compiled step whose carry is the unified `fl.RoundState`.

Two execution modes share that step bit-for-bit:

* `run(mode="stepwise")` (and `step()`) — one jit dispatch +
  `device_get` per round (the per-round tests' path, and the easiest
  to poke at).
* `run(mode="scanned")` — the whole run as chunked `lax.scan` blocks
  with host-side early exit between blocks (`driver.run_rounds`),
  removing the per-round dispatch/sync overhead entirely. Table-I
  semantics (eval cadence, rounds-to-target) are preserved exactly.
  (`run_scanned()` survives as a warn-once deprecation shim.)
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.core import driver as driver_mod
from repro.core import fl as fl_mod
from repro.data.synthetic import Dataset
from repro.models import small
from repro.telemetry import schema as tel_schema
from repro.telemetry import sinks as tel_sinks


def fixed_arrival_schedule(delays, drops):
    """Explicit per-tick arrival schedule for the buffered server.

    `delays` is (T, K) int — the arrival delay (in server ticks; 0 = on
    time) of each of tick t's K candidate reports — and `drops` is
    (T, K) bool — reports lost in transit (never admitted). Returns an
    `arrival_fn(tick) -> (delay, drop)` for `fl.make_round_fn` /
    `FedServer(arrival_fn=)`, replacing the config's random
    straggle/dropout draw with this deterministic schedule (the
    straggler-semantics tests pin exact flush behaviour with it). Ticks
    at or beyond T reuse the last row — make it zeros/False for an
    all-on-time tail.
    """
    delays = jnp.asarray(delays, jnp.int32)
    drops = jnp.asarray(drops, bool)
    if delays.shape != drops.shape:
        raise ValueError(
            f"delays {delays.shape} and drops {drops.shape} must be the "
            "same (T, K) shape")
    t_max = delays.shape[0] - 1

    def arrival_fn(tick):
        t = jnp.minimum(jnp.asarray(tick, jnp.int32), t_max)
        return delays[t], drops[t]

    return arrival_fn


@dataclasses.dataclass
class History:
    accuracy: list
    loss: list
    divergence: list
    rounds_to_target: Optional[int]
    final_accuracy: float
    thetas: list  # per-round smoothed angles of the selected clients
    weights: list


class FedServer:
    """Cross-device FL simulation, device-resident (paper Section V)."""

    def __init__(
        self,
        model: str,  # "mlr" | "cnn"
        fl: fl_mod.FLConfig,
        nodes: list,  # list[Dataset]
        test: Dataset,
        batch_size: int,
        seed: int = 0,
        angle_pred=None,
        mesh=None,
        arrival_fn=None,
    ):
        # fl.engine selects the round execution path ("tree" reference,
        # the flat-buffer Pallas path, or the client-sharded
        # "flat_sharded" variant — the latter needs `mesh`) and
        # fl.angle_filter the built-in angle predicate; all flow through
        # make_round_fn unchanged. fl.transport compresses the client
        # uplink and fl.downlink the server broadcast (optionally
        # delta-encoded via fl.downlink_delta); the EF residual carries
        # live inside the RoundState. fl.aggregation="buffered" turns
        # each step into a buffered-async server tick; `arrival_fn`
        # (fixed_arrival_schedule) then overrides the config's random
        # straggler/dropout draw.
        self.fl = fl
        self.nodes = nodes
        self.test = test
        self.batch_size = batch_size
        init_fn, self.apply_fn = small.MODELS[model]

        def loss_fn(params, batch):
            x, y = batch
            return small.classification_loss(self.apply_fn, params, x, y)

        self.data = driver_mod.stack_nodes(nodes, batch_size)
        eval_fn = driver_mod.make_eval_fn(self.apply_fn, test.x, test.y)
        self._step_fn = driver_mod.make_step_fn(
            loss_fn, fl, self.data, eval_fn=eval_fn, angle_pred=angle_pred,
            mesh=mesh, arrival_fn=arrival_fn)
        self._step_jit = jax.jit(self._step_fn)
        self._run_block = driver_mod.make_scan_runner(self._step_fn)

        def fresh_state(s: int) -> fl_mod.RoundState:
            # one seed, two independent streams: weight init and the
            # driver's selection/batching RNG must not share key material
            k_init, k_drv = jax.random.split(jax.random.key(s))
            return fl_mod.init_round_state(fl, init_fn(k_init), seed=k_drv)

        self._fresh_state = fresh_state
        self._seed = seed
        self.state = fresh_state(seed)

    def reset(self, seed: Optional[int] = None) -> None:
        """Reinitialize the RoundState (fresh params, angles, RNG stream)
        WITHOUT re-jitting — e.g. warm the jit cache with a throwaway
        round, then reset before a timed or recorded run."""
        self.state = self._fresh_state(self._seed if seed is None else seed)

    # RoundState is the single source of truth; these views keep the
    # pre-refactor attribute surface (checkpointing, tests, examples).
    @property
    def params(self):
        return self.state.params

    @property
    def angle_state(self):
        return self.state.angle

    @property
    def round(self) -> int:
        return int(self.state.round)

    def step(self, eval_every: int = 0) -> dict:
        """One stepwise round; returns host metrics. eval_every > 0 adds
        metrics["accuracy"] after rounds where (r+1) % eval_every == 0
        (-1.0 on other rounds)."""
        self.state, metrics = self._step_jit(self.state,
                                             jnp.int32(eval_every))
        return jax.device_get(metrics)

    def evaluate(self) -> float:
        """Host-side test accuracy of the current master params."""
        return small.accuracy(self.apply_fn, self.state.params,
                              self.test.x, self.test.y)

    def run(self, rounds: int, target_acc: Optional[float] = None,
            eval_every: int = 1, *, mode: str = "stepwise",
            verbose: bool = False, block: int = 8,
            ckpt_dir: Optional[str] = None, ckpt_every_blocks: int = 1,
            ckpt_keep: int = 3, sink=None,
            telemetry_every: int = 1) -> History:
        """Train for `rounds` rounds; the single public run surface.

        mode="stepwise" dispatches one jitted step per round (the
        per-round tests' path, easiest to poke at; `verbose` prints the
        per-eval progress line). mode="scanned" runs the same step as
        chunked `lax.scan` blocks (`driver.run_rounds`): `block` rounds
        per dispatch with host early-exit between blocks, and `ckpt_dir`
        snapshotting the full RoundState at block boundaries (see
        `restore` for the other half of a kill/resume). The two modes
        share the step function bit-for-bit — only dispatch granularity
        differs — and their History semantics match exactly: per-round
        entries stop at rounds_to_target, which is the ABSOLUTE round
        index (eval cadence stays phased on `state.round` when resuming
        a mid-run state).

        `sink` (a `repro.telemetry` TelemetrySink) streams the run as
        schema events — manifest first, one ``round`` event per round
        (subsampled by `telemetry_every`), per-node FedAdp rows when the
        config has `telemetry="node"`, and a ``summary`` last. Both
        modes feed the sink through the same adapter
        (`telemetry.sinks.emit_round_block`), so the streams are
        comparable to 1e-5 — a pinned test, not a hope.
        """
        if mode not in ("stepwise", "scanned"):
            raise ValueError(
                f"unknown mode {mode!r} (expected 'stepwise' or 'scanned')")
        if sink is not None:
            tel_sinks.emit_manifest(sink, self.fl)
        start = int(self.state.round)
        if mode == "stepwise":
            hist = History([], [], [], None, 0.0, [], [])
            for r in range(rounds):
                m = self.step(eval_every=eval_every)
                self._append(hist, m)
                if sink is not None:
                    tel_sinks.emit_round_block(sink, m, start + r,
                                               every=telemetry_every)
                acc = float(m["accuracy"])
                if tel_schema.is_real_accuracy(acc):
                    hist.accuracy.append(acc)
                    if verbose:
                        print(f"round {r+1:4d} loss {m['loss']:.4f} "
                              f"acc {acc:.4f}")
                    if (target_acc and acc >= target_acc
                            and hist.rounds_to_target is None):
                        hist.rounds_to_target = start + r + 1
                        break
            hist.final_accuracy = hist.accuracy[-1] if hist.accuracy else 0.0
        else:
            self.state, ms, rtt, ran = driver_mod.run_rounds(
                self._run_block, self.state, rounds, eval_every=eval_every,
                target_acc=target_acc, block=block, ckpt_dir=ckpt_dir,
                ckpt_every_blocks=ckpt_every_blocks, ckpt_keep=ckpt_keep,
                sink=sink, telemetry_every=telemetry_every)
            hist = History([], [], [], rtt, 0.0, [], [])
            stop = rtt - start if rtt is not None else ran
            for r in range(stop):
                self._append(hist, {k: v[r] for k, v in ms.items()})
                acc = float(ms["accuracy"][r])
                if tel_schema.is_real_accuracy(acc):
                    hist.accuracy.append(acc)
            hist.final_accuracy = hist.accuracy[-1] if hist.accuracy else 0.0
        if sink is not None:
            tel_sinks.emit_summary(
                sink, rounds=int(self.state.round) - start,
                final_accuracy=hist.final_accuracy or None,
                rounds_to_target=hist.rounds_to_target,
                target_acc=target_acc)
        return hist

    _warned_run_scanned = False

    def run_scanned(self, rounds: int, target_acc: Optional[float] = None,
                    eval_every: int = 1, block: int = 8,
                    ckpt_dir: Optional[str] = None,
                    ckpt_every_blocks: int = 1,
                    ckpt_keep: int = 3) -> History:
        """Deprecated shim: use `run(..., mode="scanned")`."""
        if not FedServer._warned_run_scanned:
            warnings.warn(
                "FedServer.run_scanned(...) is deprecated; use "
                "FedServer.run(..., mode='scanned')",
                DeprecationWarning, stacklevel=2)
            FedServer._warned_run_scanned = True
        return self.run(rounds, target_acc, eval_every, mode="scanned",
                        block=block, ckpt_dir=ckpt_dir,
                        ckpt_every_blocks=ckpt_every_blocks,
                        ckpt_keep=ckpt_keep)

    def save_checkpoint(self, ckpt_dir: str, keep: int = 3) -> str:
        """Snapshot the current RoundState into `ckpt_dir` (atomic write,
        `latest` pointer), keyed by the absolute round index."""
        return ckpt_io.save_checkpoint(
            ckpt_dir, self.round, fl_mod.state_to_tree(self.state),
            keep=keep)

    def restore(self, source: str) -> int:
        """Resume from a checkpoint: `source` is a checkpoint directory
        (the `latest` pointer is followed) or a single .npz path. The
        restored RoundState is validated against — and elastically
        re-sized to — THIS server's config (`fl.state_from_tree`), so a
        fleet that grew or shrank since the snapshot restores with new
        clients at zero EF residual / unseen angle. Returns the absolute
        round index training will resume from."""
        if os.path.isdir(source):
            loaded = ckpt_io.load_latest(source)
            if loaded is None:
                raise FileNotFoundError(
                    f"no checkpoint found in directory {source!r}")
            _, tree = loaded
        else:
            tree = ckpt_io.load(source)
        state = fl_mod.state_from_tree(self.fl, tree)
        # the codec validates the state against its OWN params; the server
        # additionally pins them to this model's allocation.
        cur = jax.tree.map(lambda a: (a.shape, a.dtype), self.state.params)
        new = jax.tree.map(lambda a: (a.shape, a.dtype), state.params)
        if cur != new:
            raise ValueError(
                "checkpoint params do not match this server's model "
                f"(got {new}, want {cur})")
        self.state = state
        return self.round

    @staticmethod
    def _append(hist: History, m: dict) -> None:
        hist.loss.append(float(m["loss"]))
        hist.divergence.append(float(m["divergence"]))
        hist.thetas.append(np.asarray(m["theta_smoothed"]))
        hist.weights.append(np.asarray(m["weights"]))


def _epoch_batcher(ds: Dataset, batch_size: int, seed: int):
    """Host-side reference batcher (the driver's device pipeline replaced
    it in FedServer): yields one epoch of shuffled minibatches per call,
    (tau, B, ...) — the paper's tau = E*D_i/B with E=1."""
    n = len(ds.y)
    tau = n // batch_size
    if tau < 1:
        raise ValueError(
            f"node dataset has {n} samples but batch_size={batch_size}: "
            f"tau = {n}//{batch_size} = 0 local steps — lower batch_size "
            "or grow the node's dataset")
    rng = np.random.default_rng(seed)
    while True:
        order = rng.permutation(n)[: tau * batch_size]
        xb = ds.x[order].reshape(tau, batch_size, *ds.x.shape[1:])
        yb = ds.y[order].reshape(tau, batch_size)
        yield xb, yb
