"""Federated server loop for the paper's classification experiments.

Hosts the node datasets, performs client selection, feeds per-round
mini-batch tensors into the compiled round function, evaluates test
accuracy, and tracks rounds-to-target — the paper's Table-I metric.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import transport as transport_mod
from repro.core import fl as fl_mod
from repro.core.weighting import AngleState
from repro.data.synthetic import Dataset
from repro.models import small


@dataclasses.dataclass
class History:
    accuracy: list
    loss: list
    divergence: list
    rounds_to_target: Optional[int]
    final_accuracy: float
    thetas: list  # per-round smoothed angles of the selected clients
    weights: list


class FedServer:
    """Cross-device FL simulation on host numpy data (paper Section V)."""

    def __init__(
        self,
        model: str,  # "mlr" | "cnn"
        fl: fl_mod.FLConfig,
        nodes: list,  # list[Dataset]
        test: Dataset,
        batch_size: int,
        seed: int = 0,
        angle_pred: Optional[Callable] = None,
        mesh=None,
    ):
        # fl.engine selects the round execution path ("tree" reference,
        # the flat-buffer Pallas path, or the client-sharded
        # "flat_sharded" variant — the latter needs `mesh`) and
        # fl.angle_filter the built-in angle predicate; all flow through
        # make_round_fn unchanged.
        self.fl = fl
        self.nodes = nodes
        self.test = test
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        init_fn, self.apply_fn = small.MODELS[model]
        self.params = init_fn(jax.random.key(seed))

        def loss_fn(params, batch):
            x, y = batch
            return small.classification_loss(self.apply_fn, params, x, y)

        self.round_fn = jax.jit(
            fl_mod.make_round_fn(loss_fn, fl, angle_pred=angle_pred,
                                 mesh=mesh))
        self.angle_state = AngleState.init(fl.num_clients)
        self.prev_delta = fl_mod.init_prev_delta(self.params)
        # fl.transport compresses the client uplink and fl.downlink the
        # server broadcast; with the respective error_feedback flags the
        # quantization residuals are carried between rounds (per-client
        # rows for the uplink, one server-side vector for the downlink).
        n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(self.params))
        self.ef_state = None
        if fl.error_feedback:
            self.ef_state = transport_mod.init_error_feedback(
                fl.num_clients, n)
        self.dl_state = None
        if fl.downlink_error_feedback:
            self.dl_state = (
                transport_mod.downlink.init_downlink_error_feedback(n))
        self.round = 0
        self._iters = [
            _epoch_batcher(ds, batch_size, seed + 17 * i)
            for i, ds in enumerate(nodes)
        ]

    def _select(self) -> np.ndarray:
        k = self.fl.clients_per_round
        if k >= self.fl.num_clients:
            return np.arange(self.fl.num_clients)
        return self.rng.choice(self.fl.num_clients, size=k, replace=False)

    def _round_batches(self, sel: np.ndarray):
        xs, ys = [], []
        for i in sel:
            bx, by = next(self._iters[i])
            xs.append(bx)
            ys.append(by)
        return (
            jnp.asarray(np.stack(xs)),  # (K, tau, B, ...)
            jnp.asarray(np.stack(ys)),
        )

    def step(self) -> dict:
        sel = self._select()
        batches = self._round_batches(sel)
        sizes = jnp.asarray([len(self.nodes[i].y) for i in sel], jnp.float32)
        args = (self.params, self.angle_state, self.prev_delta, batches,
                jnp.asarray(sel, jnp.int32), sizes, jnp.int32(self.round))
        # round_fn appends new_ef / new_dl to its outputs in that order
        # when the matching EF state is threaded (see fl.make_round_fn).
        kw = {}
        if self.ef_state is not None:
            kw["ef_state"] = self.ef_state
        if self.dl_state is not None:
            kw["dl_state"] = self.dl_state
        outs = self.round_fn(*args, **kw)
        (self.params, self.angle_state, self.prev_delta, metrics), rest = (
            outs[:4], list(outs[4:]))
        if self.ef_state is not None:
            self.ef_state = rest.pop(0)
        if self.dl_state is not None:
            self.dl_state = rest.pop(0)
        self.round += 1
        return jax.device_get(metrics)

    def evaluate(self) -> float:
        return small.accuracy(self.apply_fn, self.params, self.test.x, self.test.y)

    def run(self, rounds: int, target_acc: Optional[float] = None,
            eval_every: int = 1, verbose: bool = False) -> History:
        hist = History([], [], [], None, 0.0, [], [])
        for r in range(rounds):
            m = self.step()
            hist.loss.append(float(m["loss"]))
            hist.divergence.append(float(m["divergence"]))
            hist.thetas.append(np.asarray(m["theta_smoothed"]))
            hist.weights.append(np.asarray(m["weights"]))
            if (r + 1) % eval_every == 0:
                acc = self.evaluate()
                hist.accuracy.append(acc)
                if verbose:
                    print(f"round {r+1:4d} loss {m['loss']:.4f} acc {acc:.4f}")
                if target_acc and acc >= target_acc and hist.rounds_to_target is None:
                    hist.rounds_to_target = r + 1
                    break
        hist.final_accuracy = hist.accuracy[-1] if hist.accuracy else 0.0
        return hist


def _epoch_batcher(ds: Dataset, batch_size: int, seed: int):
    """Yields one epoch of shuffled minibatches per call: (tau, B, ...) —
    the paper's tau = E*D_i/B with E=1."""
    rng = np.random.default_rng(seed)
    n = len(ds.y)
    tau = n // batch_size
    while True:
        order = rng.permutation(n)[: tau * batch_size]
        xb = ds.x[order].reshape(tau, batch_size, *ds.x.shape[1:])
        yb = ds.y[order].reshape(tau, batch_size)
        yield xb, yb
