"""Federated round engines: FedAdp / FedAvg as one compiled program.

Two execution modes (DESIGN.md §6):

* ``parallel`` — the K participating clients are vmapped; on a mesh the
  client axis is sharded over ("pod", "data"). Per-client deltas are
  materialized stacked (K, ...), angles are batched reductions, and the
  weighted aggregation is one collective contraction over the client axis.
  This is the faithful high-throughput path for models that fit K-way.

* ``sequential`` — one model copy (FSDP-shardable), clients advanced by
  `lax.scan`. FedAdp needs the round's global gradient *before* weighting,
  so the exact variant runs TWO passes (local training recomputed in pass
  2 — compute x2, memory x1/K). The key identity making two (not three)
  passes suffice: softmax weights factor as w_i = D_i e^{f(θ̃_i)} with a
  scalar denominator, so pass 2 can accumulate Σ w_i Δ_i and Σ w_i online.

  ``stale_angles=True`` is the beyond-paper one-pass variant: angles are
  measured against the *previous* round's aggregated delta (one-round
  staleness), restoring pass-1-only compute. Evaluated in EXPERIMENTS.md.

Both modes compute their angle statistics through ONE implementation —
the fused `kernels.round_stats` Pallas kernel (client-chunked, any K):
parallel flat engines feed it the stacked (K, N) buffer (optionally
client-row-sharded under shard_map), the sequential scan feeds it one
(1, N) row per client.

Angle convention: the paper defines θ_i between ∇F and ∇F_i with
∇F_i = -Δ_i/η (Alg. 1 l.9); the -1/η factors cancel in the cosine, so we
correlate deltas directly.

Round-state contract: every engine threads ONE `RoundState` pytree — the
server-side carry of a federated round (params, Eq. 9 angle state, the
previous aggregated delta, both error-feedback residuals, the previous
broadcast for delta-encoded downlinks, the device RNG key, and the round
counter). `round_fn(state, batches, sel_idx, data_sizes) -> (state,
metrics)` is the uniform signature for parallel tree/flat/flat_sharded
and the sequential scan alike, which is what lets `core.driver` fold a
whole training run into a single `lax.scan` with the state as the carry.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import transport as transport_mod
from repro.core import fl_shard_map, treemath, weighting
from repro.core import buffer as buffer_mod
from repro.core.weighting import AngleState
from repro.kernels import round_stats as round_stats_mod
from repro.kernels import weighted_agg as weighted_agg_mod

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FLConfig:
    num_clients: int  # N — population size (angle-state slots)
    clients_per_round: int  # K = |S_t|
    local_steps: int  # tau
    method: str = "fedadp"  # fedadp | fedavg | fedprox
    alpha: float = weighting.DEFAULT_ALPHA
    base_lr: float = 0.01
    lr_decay: float = 0.995  # per communication round (paper Sec. V)
    mode: str = "parallel"  # parallel | sequential
    stale_angles: bool = False  # sequential one-pass variant
    # parallel-mode execution engine:
    #   "tree" — per-leaf treemath reductions (reference; keeps sharded
    #            leaves sharded, the right trade on a model-sharded mesh)
    #   "flat" — deltas raveled once into a contiguous (K, N) f32 buffer;
    #            angle stats + aggregation run as single-HBM-pass Pallas
    #            kernels (round_stats / weighted_agg). The client axis is
    #            CHUNKED inside the kernels (<= kernels.weighted_agg.K_TILE
    #            clients per VMEM tile), so any K is supported — there is
    #            no MAX_K ceiling.
    #   "flat_sharded" — the flat buffer row-sharded over the mesh client
    #            axis ("pod","data"); the WHOLE round (per-shard kernel
    #            calls, stat psums, replicated weighting, aggregate psum)
    #            is one shard_map region via fl_shard_map.make_round_ops.
    #            Requires passing `mesh=` to make_round_fn; any
    #            clients_per_round works (K % shards != 0 zero-pads the
    #            client axis — padded rows get exactly zero weight).
    #            On a 2D (client x model) mesh — a "model" axis of size
    #            > 1 — the buffer becomes a (client x model) grid of
    #            (K_loc, N_loc) tiles (fl_shard_map.make_round_ops_2d):
    #            each device ravels its LOCAL model-shard leaf blocks
    #            (no all-gather), quantizes them shard-locally (scale
    #            chunks never straddle a model-axis split — the 2D wire
    #            layout), and the aggregated delta keeps model-sharded
    #            leaves sharded. The tree engine on the same mesh
    #            consumes the identical shard-local wire via a blocked
    #            quantize->dequantize roundtrip, so tree and flat still
    #            agree to 1e-5 per transport. error_feedback is
    #            incompatible with a quantized 2D wire (the residual is
    #            a global tree-ravel-order buffer) and raises.
    # The sequential mode's pass-2 statistics also stream through the
    # round_stats kernel (K=1 rows against the raveled global delta), so
    # all modes share one stats implementation.
    engine: str = "tree"  # tree | flat | flat_sharded
    # Delta transport — the client-uplink wire format (repro.transport):
    #   "f32"  — reference wire, deltas ship unmodified.
    #   "bf16" — 2 bytes/param; the flat engines read the bf16 buffer
    #            directly (the kernels' in-VMEM astype IS the dequant).
    #   "int8" — 1 byte/param + one f32 scale per (client, kernel chunk);
    #            the flat engines run the fused in-register-dequant kernels
    #            (round_stats_q / weighted_agg_q) so stats + aggregation
    #            stay one HBM pass over ~4x fewer bytes. The tree engine
    #            NEVER reads quantized buffers: it dequantizes back to the
    #            stacked tree and runs the per-leaf reference reductions.
    #   "int4" — two params per byte (packed nibble pairs) + one f32 scale
    #            per (client, `group_size` elements); the flat engines run
    #            the grouped-scale fused kernels (round_stats_q4 /
    #            weighted_agg_q4) — one HBM pass over ~8x fewer bytes.
    transport: str = "f32"  # f32 | bf16 | int8 | int4
    # int4 scale-group width: one f32 dequant scale per `group_size`
    # consecutive elements of a client's flat delta row. Must be even and
    # divide kernels' CHUNK = ROWS*LANE = 16384 (so a packed byte never
    # straddles a group and kernel tiles cover whole groups); smaller
    # groups track local magnitude better at 4/group_size bytes/param of
    # side data. Ignored by the other transports (int8 stays per-chunk).
    group_size: int = transport_mod.GROUP_SIZE
    # Server->client broadcast (downlink) wire format
    # (repro.transport.downlink): "f32" is the reference broadcast (the
    # round is then byte-identical upstream of this option); "bf16"/"int8"
    # compress the global model once per round and EVERY engine trains its
    # clients from the same dequantized reconstruction, so engine parity
    # is preserved by construction. The server always applies the
    # aggregated delta to its own uncompressed master params.
    downlink: str = "f32"  # f32 | bf16 | int8
    # Delta-encode the broadcast: ship the quantized model DIFF against
    # the previous broadcast reconstruction instead of the full model
    # (`transport.downlink.delta_compress` on the raveled (1, N) diff).
    # Per-round deltas are orders of magnitude smaller than the params
    # themselves, so the same wire format reconstructs them far more
    # accurately (the int8 scale tracks the diff's absmax, not the
    # model's). Requires downlink != "f32" (an exact broadcast has no
    # reason to diff) and threads `RoundState.bcast` — a
    # `transport.downlink.BroadcastState` with the server's chain head,
    # a `downlink_ring`-deep ring of the last delta reconstructions, and
    # a per-client (num_clients,) last-pulled-version vector, so a
    # selected (or buffered-admitted) client decodes against the base it
    # ACTUALLY holds: it replays the ring's deltas since its last pull
    # (bitwise the server head), or — if it never pulled / fell more
    # than `downlink_ring` versions behind — receives a full quantized
    # model instead (catch-up resync). Round 0 broadcasts the full model
    # to everyone. Composes with downlink_error_feedback (the EF
    # residual rides on the diff before compression).
    downlink_delta: bool = False
    # Ring depth R of the per-client delta-downlink state: the server
    # retains the delta reconstructions of the last R broadcast versions,
    # so a client up to R versions stale can catch up by replaying
    # deltas; staler clients pay a full-model resync. Memory is R * N
    # f32 on device. Only meaningful with downlink_delta=True.
    downlink_ring: int = 8
    # Carry the per-client quantization residual across rounds (EF-SGD) so
    # the compressed angle statistics stay unbiased over time. Requires
    # transport != "f32" and parallel mode; the residual lives in
    # `RoundState.ef` — a (num_clients, N) f32 array
    # (transport.init_error_feedback) that `init_round_state` allocates
    # and round_fn updates in place of the old trailing ef_state output.
    error_feedback: bool = False
    # Server-side EF mirror for the downlink: carry the broadcast residual
    # params - dequant(quant(params)) across rounds so the model the
    # clients see is unbiased over time. Requires downlink != "f32"; the
    # residual lives in `RoundState.dl_ef` — an (N,) f32 vector
    # (transport.downlink.init_downlink_error_feedback) allocated by
    # `init_round_state` and updated by round_fn each round.
    downlink_error_feedback: bool = False
    # Pallas interpret mode for engine="flat": None = auto (interpret
    # everywhere except a real TPU backend), or force True/False.
    interpret: Optional[bool] = None
    # beyond-paper: restrict angle statistics to non-expert parameters —
    # MoE routing makes expert deltas sparse/noisy, polluting the cosine.
    angle_filter: str = "all"  # all | dense_only
    # fedprox (Li et al. 2018) baseline: mu/2 ||w - w_global||^2 proximal term
    prox_mu: float = 0.0
    # Server aggregation discipline:
    #   "sync"     — the paper's lockstep round: every selected node
    #                reports before the server re-weights by angle.
    #   "buffered" — FedBuff-style buffered-async server (core.buffer):
    #                reports are admitted continuously into a K-slot
    #                device-resident buffer (`RoundState.buf`) with
    #                simulated arrival delays/dropouts, and the server
    #                flushes whenever `buffer_m` of the in-flight cohort
    #                have landed, folding a staleness discount into the
    #                FedAdp Gompertz weight (late low-contribution nodes
    #                are doubly suppressed). Requires mode="parallel".
    #                With buffer_m == K and no stragglers/dropouts it
    #                reproduces the sync round bit-for-bit.
    aggregation: str = "sync"  # sync | buffered
    # Buffered flush threshold M: aggregate when >= buffer_m reports of
    # the in-flight cohort have landed. 0 (default) means M = K =
    # clients_per_round — flush only when the whole cohort landed.
    buffer_m: int = 0
    # Staleness decay rate: a report applied `age` model versions after
    # its client pulled params is discounted by exp(-staleness_beta*age)
    # inside the aggregation weights (weighting.staleness_discount).
    staleness_beta: float = 0.3
    # Simulated arrival-time injection (buffered mode): each admitted
    # report straggles with probability `straggle_prob` (arrival delayed
    # uniformly in {1..straggle_max} server ticks) and is dropped in
    # transit with probability `dropout_prob` (never arrives; the slot
    # re-admits a fresh client next tick). Drawn from the device RNG —
    # a fixed seed is a fixed schedule; `make_round_fn(arrival_fn=)`
    # overrides the draw entirely (core.server.fixed_arrival_schedule).
    straggle_prob: float = 0.0
    straggle_max: int = 1
    dropout_prob: float = 0.0
    # Round-level telemetry (repro.telemetry). None (default) is the
    # zero-overhead off path: the metrics dict — and therefore the
    # compiled step's jaxpr — is byte-identical to a telemetry-free
    # build. "node" makes every engine's metrics dict additionally carry
    # the per-node FedAdp internals under flat "tel/*" keys (they stack
    # naturally under lax.scan): "tel/nodes" (K,) population attribution
    # for this round's theta/weights rows, "tel/cohort" (num_clients,)
    # selected mask, "tel/weight_entropy", and the wire cost
    # "tel/bytes_up"/"tel/bytes_down" (transport.round_bytes); buffered
    # mode adds "tel/ages", "tel/landed", and "tel/occupancy". The
    # host-side adapter is telemetry.sinks.emit_round_block.
    telemetry: Optional[str] = None  # None | "node"

    def validate(self) -> "FLConfig":
        """Check the config's cross-field invariants in one place.

        Raises ValueError naming the offending field. Called by both
        `make_round_fn` and `init_round_state`, so an invalid config
        fails before any buffer is allocated or a round is traced.
        Returns self so it chains: `cfg = FLConfig(...).validate()`.
        """
        if self.mode not in ("parallel", "sequential"):
            raise ValueError(
                f"unknown mode {self.mode!r} (expected 'parallel' or "
                "'sequential')")
        if self.method not in ("fedadp", "fedavg", "fedprox"):
            raise ValueError(
                f"unknown method {self.method!r} (expected 'fedadp', "
                "'fedavg', or 'fedprox')")
        if self.engine not in ("tree", "flat", "flat_sharded"):
            raise ValueError(f"unknown engine {self.engine!r}")
        if self.angle_filter not in ("all", "dense_only"):
            raise ValueError(f"unknown angle_filter {self.angle_filter!r}")
        if self.telemetry not in (None, "node"):
            raise ValueError(
                f"unknown telemetry {self.telemetry!r} (expected None — "
                "the zero-overhead off path — or 'node' for per-node "
                "round metrics)")
        if self.transport not in transport_mod.TRANSPORTS:
            raise ValueError(
                f"unknown transport {self.transport!r} (expected one of "
                f"{transport_mod.TRANSPORTS})")
        if self.downlink not in transport_mod.DOWNLINKS:
            raise ValueError(
                f"unknown downlink {self.downlink!r} (expected one of "
                f"{transport_mod.DOWNLINKS})")
        if self.transport == "int4":
            transport_mod.validate_group_size(self.group_size)
        if self.error_feedback and self.transport == "f32":
            raise ValueError(
                "error_feedback carries the quantization residual; "
                "transport='f32' has none (set transport='bf16', 'int8', "
                "or 'int4')")
        if self.downlink_error_feedback and self.downlink == "f32":
            raise ValueError(
                "downlink_error_feedback carries the broadcast "
                "quantization residual; downlink='f32' has none (set "
                "downlink='bf16' or 'int8')")
        if self.downlink_delta and self.downlink == "f32":
            raise ValueError(
                "downlink_delta broadcasts the quantized model diff "
                "against the previous broadcast; downlink='f32' ships "
                "exact params and has nothing to gain from it (set "
                "downlink='bf16' or 'int8')")
        if self.downlink_delta and self.downlink_ring < 1:
            raise ValueError(
                f"downlink_ring={self.downlink_ring} must be >= 1 (the "
                "server retains the last R broadcast deltas; a client "
                "more than R versions behind is resynced in full)")
        if not self.downlink_delta and self.downlink_ring != 8:
            raise ValueError(
                f"downlink_ring={self.downlink_ring} requires "
                "downlink_delta=True (without delta encoding every "
                "broadcast ships the full model and no ring is kept)")
        if self.mode == "sequential":
            if self.engine != "tree":
                raise ValueError(
                    f"engine={self.engine!r} requires mode='parallel' "
                    "(sequential mode never materializes the stacked "
                    "(K, N) delta buffer; its stats already stream "
                    "through round_stats)")
            if self.transport != "f32":
                raise ValueError(
                    "transport compresses the stacked parallel uplink "
                    "buffer; sequential mode streams one client at a "
                    "time (use mode='parallel' for quantized transport)")
            if self.downlink != "f32":
                raise ValueError(
                    "quantized downlink is threaded through the parallel "
                    "round engines; use mode='parallel' for downlink != "
                    "'f32'")
        if self.aggregation not in ("sync", "buffered"):
            raise ValueError(
                f"unknown aggregation {self.aggregation!r} (expected "
                "'sync' or 'buffered')")
        if self.aggregation == "buffered":
            if self.mode != "parallel":
                raise ValueError(
                    "aggregation='buffered' admits reports into the "
                    "stacked (K, N) uplink buffer and requires "
                    "mode='parallel'")
            if self.stale_angles:
                raise ValueError(
                    "stale_angles is the sequential one-pass variant; "
                    "aggregation='buffered' already measures angles at "
                    "flush time (unset stale_angles)")
            if not 0 <= self.buffer_m <= self.clients_per_round:
                raise ValueError(
                    f"buffer_m={self.buffer_m} must be in "
                    f"[0, clients_per_round={self.clients_per_round}] "
                    "(0 means flush only when the whole cohort landed)")
            if self.staleness_beta < 0:
                raise ValueError(
                    f"staleness_beta={self.staleness_beta} must be >= 0 "
                    "(the discount is exp(-staleness_beta * age))")
            if not 0.0 <= self.straggle_prob <= 1.0:
                raise ValueError(
                    f"straggle_prob={self.straggle_prob} must be a "
                    "probability in [0, 1]")
            if not 0.0 <= self.dropout_prob <= 1.0:
                raise ValueError(
                    f"dropout_prob={self.dropout_prob} must be a "
                    "probability in [0, 1]")
            if self.straggle_prob > 0 and self.straggle_max < 1:
                raise ValueError(
                    f"straggle_max={self.straggle_max} must be >= 1 when "
                    "straggle_prob > 0 (stragglers delay by 1..max ticks)")
        else:
            for field, val, default in (
                    ("buffer_m", self.buffer_m, 0),
                    ("straggle_prob", self.straggle_prob, 0.0),
                    ("dropout_prob", self.dropout_prob, 0.0)):
                if val != default:
                    raise ValueError(
                        f"{field}={val} requires aggregation='buffered' "
                        "(the sync round is lockstep: every report lands "
                        "before the server aggregates)")
        return self


class RoundState(NamedTuple):
    """The unified server-side carry of a federated round.

    One pytree threaded identically through every engine (tree / flat /
    flat_sharded / sequential): `round_fn(state, batches, sel_idx,
    data_sizes) -> (state, metrics)`. Because the whole carry is a single
    pytree with a STATIC structure, `core.driver` can scan it over rounds
    (`lax.scan`) and donate its buffers so params/EF update in place.

    Optional fields are None when the matching FLConfig flag is off —
    None is an empty pytree, so the carry structure stays fixed per
    config and the scan carry never changes shape.
    """

    params: PyTree  # the server's uncompressed master model
    angle: AngleState  # Eq. 9 smoothed angles + participation counts
    prev_delta: PyTree  # last aggregated global delta, f32 leaves
    #   (the stale_angles reference; threaded untouched otherwise)
    ef: Optional[jax.Array] = None  # (num_clients, N) uplink EF residual
    dl_ef: Optional[jax.Array] = None  # (N,) downlink EF residual
    bcast: Optional[transport_mod.downlink.BroadcastState] = None
    #   per-client downlink-delta state (downlink_delta): the broadcast
    #   chain head, the R-deep ring of the last delta reconstructions,
    #   and each client's last-pulled version (see transport.downlink)
    buf: Optional[buffer_mod.ReportBuffer] = None  # buffered-async report
    #   buffer: (K, N) in-flight report rows + per-row staleness
    #   bookkeeping (aggregation="buffered"; see core.buffer)
    rng: Optional[jax.Array] = None  # device PRNG key — owned by the
    #   data/selection pipeline (core.driver); round_fn threads it as-is
    round: Any = 0  # i32 round counter (drives the lr schedule)


def param_count(params: PyTree) -> int:
    """Total scalar parameter count N (the flat-buffer width)."""
    return sum(math.prod(p.shape) for p in jax.tree.leaves(params))


def init_round_state(fl: FLConfig, params: PyTree,
                     seed: "int | jax.Array" = 0) -> RoundState:
    """Fresh RoundState for `params` under `fl`.

    Allocates exactly the optional buffers the config calls for (uplink
    EF rows, downlink EF vector, per-client broadcast state, buffered
    report buffer) so the state structure is a pure function of the
    config — `fl.validate()` runs first, so an inconsistent config fails
    here rather than at trace time. `seed` is an int (a new
    `jax.random.key` is made) or an existing PRNG key array.
    """
    fl.validate()
    n = param_count(params)
    rng = seed if isinstance(seed, jax.Array) else jax.random.key(seed)
    return RoundState(
        params=params,
        angle=AngleState.init(fl.num_clients),
        prev_delta=init_prev_delta(params),
        ef=(transport_mod.init_error_feedback(fl.num_clients, n)
            if fl.error_feedback else None),
        dl_ef=(transport_mod.downlink.init_downlink_error_feedback(n)
               if fl.downlink_error_feedback else None),
        bcast=(transport_mod.downlink.init_broadcast_state(
            n, fl.num_clients, fl.downlink_ring)
            if fl.downlink_delta else None),
        buf=(buffer_mod.init_report_buffer(fl.clients_per_round, n)
             if fl.aggregation == "buffered" else None),
        rng=rng,
        round=jnp.int32(0),
    )


def state_to_tree(state: RoundState) -> dict:
    """RoundState -> a nested dict `checkpoint.io.save` can round-trip.

    Field-for-field: NamedTuples become dicts, optional fields stay None
    (the io layer writes `__none__` sentinels so the structure survives),
    and the typed PRNG key ships as-is (io serializes it via
    `jax.random.key_data` + an impl tag). `state_from_tree` is the
    inverse."""
    return {
        "params": state.params,
        "angle": {"smoothed": state.angle.smoothed,
                  "count": state.angle.count},
        "prev_delta": state.prev_delta,
        "ef": state.ef,
        "dl_ef": state.dl_ef,
        "bcast": (None if state.bcast is None else state.bcast._asdict()),
        "buf": (None if state.buf is None else state.buf._asdict()),
        "rng": state.rng,
        "round": state.round,
    }


def _resize_rows(a: jax.Array, k_new: int, fill=0) -> jax.Array:
    """Truncate / pad axis 0 to `k_new` rows (elastic-K restore).

    New rows are `fill` — zero for angle/EF state (fresh clients start
    like round-0 clients), `downlink.NEVER_PULLED` for the broadcast
    version vector (fresh clients need a full-model resync)."""
    k_old = a.shape[0]
    if k_new == k_old:
        return a
    if k_new < k_old:
        return a[:k_new]
    pad = jnp.full((k_new - k_old,) + a.shape[1:], fill, a.dtype)
    return jnp.concatenate([a, pad])


def state_from_tree(cfg: FLConfig, tree: dict) -> RoundState:
    """Rebuild a RoundState from `state_to_tree`'s dict under `cfg`.

    The restored state's pytree structure is the CONFIG's — each optional
    field (ef / dl_ef / bcast) must be present exactly when the matching
    flag is on, and every leaf is validated (shape AND dtype) against
    `init_round_state`'s template, so a checkpoint from a different model
    or an incompatible config fails loudly instead of mis-resuming.

    Elastic-K: when `cfg.num_clients` differs from the checkpoint's, the
    per-client state is re-sized — AngleState rows, uplink-EF rows, and
    the broadcast version vector `bcast.ver` are truncated (shrink) or
    padded (grow). New clients therefore start exactly like round-0
    clients: zero EF residual, unseen angle (smoothed=0, count=0), and a
    `NEVER_PULLED` broadcast version (their first selection is a
    full-model resync). Departed clients' slots are dropped. The
    per-model state (dl_ef, params, the broadcast ring/head) is
    K-independent and restores bit-exactly; a `downlink_ring` mismatch
    fails the template shape check below.

    Checkpoints from the pre-ring repo carried a single shared
    'prev_broadcast' vector — per-client decode bases cannot be
    reconstructed from it, so such trees are rejected with a pointed
    error rather than silently mis-upgraded.

    Old-style raw `uint32` PRNG keys (pre-typed-key checkpoints) are
    wrapped back into a typed key via `jax.random.wrap_key_data` with the
    default impl.
    """
    missing = [k for k in ("params", "angle", "prev_delta", "rng", "round")
               if tree.get(k) is None]
    if missing:
        raise ValueError(
            f"checkpoint tree lacks required RoundState fields {missing} "
            "— was it written by fl.state_to_tree?")
    if tree.get("prev_broadcast") is not None:
        raise ValueError(
            "checkpoint carries the legacy shared 'prev_broadcast' vector "
            "— it was written by a pre-ring repo revision whose "
            "downlink-delta state had no per-client decode bases; the "
            "per-client BroadcastState (ring/head/ver) cannot be "
            "reconstructed from it. Re-run the training (or restore under "
            "the revision that wrote it)")
    for name, flag, want in (
            ("ef", "error_feedback", cfg.error_feedback),
            ("dl_ef", "downlink_error_feedback", cfg.downlink_error_feedback),
            ("bcast", "downlink_delta", cfg.downlink_delta)):
        have = tree.get(name) is not None
        if want and not have:
            raise ValueError(
                f"cfg.{flag}=True but the checkpoint has no {name!r} — it "
                "was written under a config with the feature off; restore "
                "with a matching config (or re-init that buffer yourself)")
        if have and not want:
            raise ValueError(
                f"checkpoint carries {name!r} but cfg.{flag}=False — "
                "dropping a live residual would silently change the run; "
                "restore with a matching config")
    buffered = cfg.aggregation == "buffered"
    have_buf = tree.get("buf") is not None
    if buffered and not have_buf:
        raise ValueError(
            "cfg.aggregation='buffered' but the checkpoint has no 'buf' — "
            "it was written by a sync-aggregation run; restore with a "
            "matching config (or re-init the report buffer yourself)")
    if have_buf and not buffered:
        raise ValueError(
            "checkpoint carries 'buf' but cfg.aggregation='sync' — "
            "dropping the in-flight reports would silently change the "
            "run; restore with a matching config")

    params = tree["params"]
    rng = tree["rng"]
    if not jax.dtypes.issubdtype(rng.dtype, jax.dtypes.prng_key):
        rng = jax.random.wrap_key_data(jnp.asarray(rng, jnp.uint32))
    angle = AngleState(
        smoothed=_resize_rows(jnp.asarray(tree["angle"]["smoothed"],
                                          jnp.float32), cfg.num_clients),
        count=_resize_rows(jnp.asarray(tree["angle"]["count"], jnp.int32),
                           cfg.num_clients),
    )
    ef = tree.get("ef")
    if ef is not None:
        ef = _resize_rows(ef, cfg.num_clients)
    bcast = tree.get("bcast")
    if bcast is not None:
        bcast = transport_mod.downlink.BroadcastState(
            ring=jnp.asarray(bcast["ring"], jnp.float32),
            head=jnp.asarray(bcast["head"], jnp.float32),
            head_ver=jnp.asarray(bcast["head_ver"], jnp.int32),
            ver=_resize_rows(jnp.asarray(bcast["ver"], jnp.int32),
                             cfg.num_clients,
                             fill=transport_mod.downlink.NEVER_PULLED),
        )
    buf = tree.get("buf")
    if buf is not None:
        # in-flight reports restore verbatim (K = clients_per_round rows;
        # a K mismatch fails the template check below — resizing a report
        # buffer would orphan live slot ids, unlike the elastic per-client
        # state above).
        buf = buffer_mod.ReportBuffer(
            data=jnp.asarray(buf["data"], jnp.float32),
            slot=jnp.asarray(buf["slot"], jnp.int32),
            sizes=jnp.asarray(buf["sizes"], jnp.float32),
            age=jnp.asarray(buf["age"], jnp.int32),
            wait=jnp.asarray(buf["wait"], jnp.int32),
            free=jnp.asarray(buf["free"], bool),
        )
    state = RoundState(
        params=params, angle=angle, prev_delta=tree["prev_delta"],
        ef=ef, dl_ef=tree.get("dl_ef"), bcast=bcast, buf=buf,
        rng=rng, round=jnp.asarray(tree["round"], jnp.int32),
    )

    # validate against the config's own allocation: same pytree structure,
    # and shape/dtype equality on every leaf.
    p_sds = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    template = jax.eval_shape(lambda p: init_round_state(cfg, p), p_sds)
    got_def = jax.tree.structure(state)
    want_def = jax.tree.structure(template)
    if got_def != want_def:
        raise ValueError(
            "restored RoundState structure does not match "
            f"init_round_state({cfg.num_clients} clients): got {got_def}, "
            f"want {want_def}")
    got = jax.tree_util.tree_flatten_with_path(state)[0]
    want = jax.tree.leaves(template)
    for (path, leaf), ref in zip(got, want):
        name = jax.tree_util.keystr(path)
        if leaf.shape != ref.shape or leaf.dtype != ref.dtype:
            raise ValueError(
                f"checkpoint leaf {name} has shape {leaf.shape} dtype "
                f"{leaf.dtype}, but the config allocates {ref.shape} "
                f"{ref.dtype} — wrong model or incompatible config")
    return state


def local_update(loss_fn: Callable, params: PyTree, batches: PyTree, lr,
                 prox_mu: float = 0.0, grad_constraint: Optional[Callable] = None):
    """tau steps of SGD on one client. batches: leaves (tau, B, ...).

    prox_mu > 0 adds FedProx's proximal term mu/2 ||w - w(t-1)||^2 against
    the round's starting params (Li et al. 2018 — baseline for comparison).
    grad_constraint re-shards per-step gradients (e.g. onto the FSDP param
    spec so GSPMD reduce-scatters batch-partial grads instead of
    all-reducing the full tree — §Perf collective-term optimization).
    Returns (delta, mean_loss)."""

    if prox_mu > 0.0:
        base = loss_fn

        def loss_fn(p, b):  # noqa: F811 — intentional wrap
            prox = treemath.tree_sqnorm(treemath.tree_sub(p, params))
            return base(p, b) + 0.5 * prox_mu * prox

    def step(p, b):
        loss, g = jax.value_and_grad(loss_fn)(p, b)
        if grad_constraint is not None:
            g = grad_constraint(g)
        return treemath.tree_axpy(-lr, g, p), loss

    p_fin, losses = jax.lax.scan(step, params, batches)
    return treemath.tree_sub(p_fin, params), jnp.mean(losses)


def angle_keep_list(params: PyTree, pred: Callable) -> list:
    """One bool per leaf (flatten order): does `pred(path_keys, leaf)` keep it?"""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    keep = []
    for path, leaf in flat:
        keys = tuple(getattr(k, "key", getattr(k, "name", "")) for k in path)
        keep.append(bool(pred(keys, leaf)))
    return keep


def build_angle_mask(params: PyTree, pred: Callable) -> Callable:
    """Angle-statistics leaf filter, decided ONCE on the param tree.

    `pred(path_keys, param_leaf) -> keep?` is evaluated against the model's
    params; the returned mask then filters any tree with the same flatten
    order (params, deltas, or K-stacked deltas) down to the kept leaves —
    a list, which is itself a pytree treemath reductions accept.
    """
    keep = angle_keep_list(params, pred)

    def mask(tree):
        leaves = jax.tree_util.tree_leaves(tree)
        assert len(leaves) == len(keep), "mask/tree flatten-order mismatch"
        return [l for l, k in zip(leaves, keep) if k]

    return mask


def moe_dense_only_pred(keys, leaf) -> bool:
    """Keep everything except stacked routed-expert weights: leaves named
    w_gate/w_up/w_down under 'ffn' with an expert axis (rank >= 4 in the
    group-stacked param tree)."""
    return not (
        "ffn" in keys
        and keys[-1] in ("w_gate", "w_up", "w_down")
        and leaf.ndim >= 4
    )


def _scatter_angles(state: AngleState, sel_idx, theta):
    n = state.smoothed.shape[0]
    mask = jnp.zeros((n,), bool).at[sel_idx].set(True)
    theta_full = jnp.zeros((n,), jnp.float32).at[sel_idx].set(theta)
    return weighting.update_smoothed_angle(state, theta_full, mask)


def _scatter_angles_masked(state: AngleState, sel_idx, theta, valid):
    """Eq. 9 scatter restricted to the rows where `valid` — invalid rows
    are routed out of bounds and dropped, so a buffered flush only smooths
    the angles of the reports it actually aggregated. With `valid` all
    True this is op-for-op `_scatter_angles` (`where(True, i, n) == i` and
    an in-bounds mode="drop" scatter is the plain scatter)."""
    n = state.smoothed.shape[0]
    idx = jnp.where(valid, sel_idx, n)
    mask = jnp.zeros((n,), bool).at[idx].set(True, mode="drop")
    theta_full = jnp.zeros((n,), jnp.float32).at[idx].set(theta, mode="drop")
    return weighting.update_smoothed_angle(state, theta_full, mask)


def make_round_fn(loss_fn: Callable, fl: FLConfig,
                  delta_constraint: Optional[Callable] = None,
                  angle_pred: Optional[Callable] = None,
                  grad_constraint: Optional[Callable] = None,
                  mesh=None, arrival_fn: Optional[Callable] = None) -> Callable:
    """Build the jit-able federated round.

    round_fn(state, batches, sel_idx, data_sizes) -> (state, metrics)

    `state` is a `RoundState` (see `init_round_state`) and is threaded
    IDENTICALLY through every engine — params, Eq. 9 angle state, the
    previous aggregated delta, both EF residuals, the per-client
    broadcast state (downlink_delta), the device RNG key (untouched here; the driver's
    data pipeline owns it), and the round counter (incremented here; it
    drives the lr schedule). batches leaves: (K, tau, B, ...); sel_idx
    (K,) int32 population slots; data_sizes (K,) f32.
    `delta_constraint` optionally applies sharding constraints to the
    stacked deltas (parallel mode). `mesh` is required by
    engine="flat_sharded" (the client axis of the flat buffer is sharded
    over the mesh's ("pod","data") axes; K not divisible by the client
    axis is zero-padded before sharding). If the mesh also has a "model"
    axis of size > 1, the flat buffer becomes a 2D (client x model) tile
    grid — model-sharded leaves (models/sharding.param_pspecs rules)
    ravel shard-locally, quantization chunks are shard-local, and the
    aggregate keeps sharded leaves sharded; the TREE engine on such a
    mesh routes quantized transports through the same shard-local wire
    (fl_shard_map.make_blocked_roundtrip), so engine equivalence holds
    per transport on the 2D mesh too. Otherwise `mesh` is ignored.

    With `fl.error_feedback` the round reads and rewrites `state.ef`
    ((num_clients, N) f32, rows of unselected clients untouched); with
    `fl.downlink_error_feedback` it reads and rewrites `state.dl_ef`
    ((N,) f32). `init_round_state` allocates both; a state missing a
    required buffer raises at call time.

    `fl.downlink` != "f32" compresses the broadcast global model before
    the clients' local updates (every engine trains from the identical
    dequantized reconstruction; the aggregated delta is applied to the
    server's uncompressed master params), and `fl.transport` the client
    uplink ("int4" adds `fl.group_size`-wide grouped scales).
    `fl.downlink_delta` broadcasts the compressed diff against the
    broadcast chain head carried in `state.bcast` instead of the full
    model; `state.bcast` also tracks, per client, the last broadcast
    version pulled plus an `fl.downlink_ring`-deep ring of delta
    reconstructions, so a re-selected (or buffered-admitted) client
    decodes against the base it actually holds and a client outside the
    ring's reach is charged a full-model resync.

    When `angle_pred` is None, `fl.angle_filter` selects a built-in
    predicate ("dense_only" -> `moe_dense_only_pred`); an explicit
    `angle_pred` overrides the config.

    `fl.aggregation == "buffered"` builds the buffered-async tick instead
    of the lockstep round (same signature, same engines): reports are
    admitted into `state.buf` and the params advance only on flush ticks.
    `arrival_fn(tick) -> (delay (K,) i32, drop (K,) bool)` overrides the
    config's random straggler/dropout draw with an explicit schedule
    (`core.server.fixed_arrival_schedule`); sync mode ignores it.
    """
    fl.validate()
    if angle_pred is None and fl.angle_filter == "dense_only":
        angle_pred = moe_dense_only_pred
    if fl.engine == "flat_sharded" and mesh is None:
        raise ValueError(
            "engine='flat_sharded' shards the (K, N) delta buffer over "
            "the mesh client axis; pass mesh= to make_round_fn")
    if fl.mode == "parallel":
        if fl.aggregation == "buffered":
            return _make_buffered_round(loss_fn, fl, delta_constraint,
                                        angle_pred, grad_constraint, mesh,
                                        arrival_fn)
        return _make_parallel_round(loss_fn, fl, delta_constraint, angle_pred,
                                    grad_constraint, mesh)
    return _make_sequential_round(loss_fn, fl, angle_pred, grad_constraint)


def _lr_at(fl: FLConfig, round_idx):
    return fl.base_lr * fl.lr_decay ** jnp.asarray(round_idx, jnp.float32)


def _resolve_interpret(fl: FLConfig) -> bool:
    if fl.interpret is not None:
        return fl.interpret
    return jax.default_backend() != "tpu"


def _weight_entropy(w):
    """Shannon entropy of the (re-normalized) aggregation weights — a
    one-scalar collapse detector: ln K under FedAvg-with-equal-sizes,
    falling toward 0 as the Gompertz softmax concentrates on few nodes.
    Zero-sum rows (buffered non-flush ticks) report 0."""
    tot = jnp.sum(w)
    p = w / jnp.maximum(tot, 1e-12)
    h = -jnp.sum(jnp.where(p > 0, p * jnp.log(jnp.maximum(p, 1e-38)), 0.0))
    return jnp.where(tot > 0, h, 0.0)


def _telemetry_metrics(fl: FLConfig, params, node_ids, w, occupied=None,
                       down_split=None):
    """The `FLConfig(telemetry="node")` metric extension — ONE helper
    shared by all engines and both aggregation disciplines, so the tel/*
    key set cannot fork between them. `node_ids` attributes this round's
    theta/weights rows to population slots (sel_idx for sync rounds, the
    report buffer's slot column for buffered ticks); `occupied` masks
    rows that hold a live report (buffered; None = all rows live). The
    wire bytes are static per config (transport.round_bytes) and ride as
    constants so a telemetry stream is self-describing — EXCEPT under
    downlink_delta, where the round builders pass `down_split` =
    (delta_bytes, full_bytes): the ACTUAL per-round downlink cost (one
    delta payload per version a pulling client is behind, or a
    full-model resync), which replaces the static tel/bytes_down and
    additionally rides as tel/bytes_down_delta / tel/bytes_down_full."""
    n = param_count(params)
    rb = transport_mod.round_bytes(fl.clients_per_round, n, fl.transport,
                                   fl.downlink, group_size=fl.group_size)
    live_ids = (node_ids if occupied is None
                else jnp.where(occupied, node_ids, fl.num_clients))
    cohort = (jnp.zeros((fl.num_clients,), bool)
              .at[live_ids].set(True, mode="drop"))
    out = {
        "tel/nodes": jnp.asarray(node_ids, jnp.int32),
        "tel/cohort": cohort,
        "tel/weight_entropy": _weight_entropy(w),
        "tel/bytes_up": jnp.float32(rb["up"]),
        "tel/bytes_down": jnp.float32(rb["down"]),
    }
    if down_split is not None:
        down_delta, down_full = down_split
        out["tel/bytes_down"] = down_delta + down_full
        out["tel/bytes_down_delta"] = down_delta
        out["tel/bytes_down_full"] = down_full
    return out


def _down_byte_split(fl: FLConfig, n: int, ver_rows, v, pulled=None):
    """Actual downlink bytes for the clients pulling broadcast version
    `v` given their last-pulled versions `ver_rows`: a delta-served
    client pays one payload per version it is behind (delta and full
    payloads cost the same `wire_bytes(1, n, downlink)` on the wire —
    delta encoding buys reconstruction precision, not bytes); a resync
    client pays one full-model payload. `pulled` masks the rows that
    actually pulled this round (buffered admission; None = all).
    Returns (delta_bytes, full_bytes) as f32 scalars."""
    unit = transport_mod.wire_bytes(1, n, fl.downlink)
    resync = transport_mod.downlink.resync_mask(ver_rows, v,
                                                fl.downlink_ring)
    payloads_d = jnp.where(resync, 0, v - ver_rows)
    payloads_f = jnp.where(resync, 1, 0)
    if pulled is not None:
        payloads_d = jnp.where(pulled, payloads_d, 0)
        payloads_f = jnp.where(pulled, payloads_f, 0)
    return (jnp.sum(payloads_d).astype(jnp.float32) * unit,
            jnp.sum(payloads_f).astype(jnp.float32) * unit)


def _pad_rows(a, kp: int, fill=0.0):
    """Pad axis 0 to kp rows with a constant (client-axis shard padding).

    jnp.pad, NOT concatenate-with-a-zero-block: XLA's SPMD partitioner has
    been observed to mis-partition a concatenate feeding a shard_map region
    on 2D (client x model) host-device meshes, silently corrupting the
    padded buffer; a pad op partitions correctly.
    """
    k = a.shape[0]
    if kp == k:
        return a
    return jnp.pad(a, [(0, kp - k)] + [(0, 0)] * (a.ndim - 1),
                   constant_values=jnp.asarray(fill, a.dtype))


def _derive_param_pspecs(params, mesh):
    """UNSTACKED param PartitionSpecs for the 2D wire (config-derived:
    the same name-based rules the launch layer shards params with)."""
    from repro.models import sharding as models_sharding

    return models_sharding.param_pspecs(params, mesh)


def _make_parallel_round(loss_fn, fl: FLConfig, delta_constraint, angle_pred=None,
                         grad_constraint=None, mesh=None):
    round_ops = None
    # A mesh with a "model" axis of size > 1 switches the wire to the 2D
    # (client x model) blocked layout: quantization chunks are SHARD-LOCAL
    # (never straddling a model-axis split), model-sharded leaves are
    # raveled per shard (no all-gather), and the flat engine's aggregate
    # keeps sharded leaves sharded. The tree engine consumes the same
    # wire through fl_shard_map.make_blocked_roundtrip.
    wire_2d = (mesh is not None and fl.engine in ("tree", "flat_sharded")
               and fl_shard_map.model_axis_size(mesh) > 1)
    if wire_2d and fl.transport != "f32" and fl.error_feedback:
        raise ValueError(
            "error_feedback carries a global (num_clients, N) residual in "
            "tree-ravel order, but a (client x model) mesh quantizes the "
            "wire in shard-local blocked order; drop error_feedback or "
            "use a client-only mesh")
    if fl.engine == "flat_sharded":
        csize = fl_shard_map.client_axis_size(mesh)
        if not wire_2d:
            round_ops = fl_shard_map.make_round_ops(
                mesh, alpha=fl.alpha, method=fl.method,
                interpret=_resolve_interpret(fl), transport=fl.transport,
                group_size=fl.group_size)
            row_sharding = fl_shard_map.flat_client_sharding(mesh)
    elif wire_2d:
        csize = fl_shard_map.client_axis_size(mesh)

    def round_fn(state: RoundState, batches, sel_idx, data_sizes):
        if fl.error_feedback and state.ef is None:
            raise ValueError(
                "fl.error_feedback=True: state.ef is missing — build the "
                "state with fl.init_round_state (or "
                "transport.init_error_feedback)")
        if fl.downlink_error_feedback and state.dl_ef is None:
            raise ValueError(
                "fl.downlink_error_feedback=True: state.dl_ef is missing "
                "— build the state with fl.init_round_state (or "
                "transport.downlink.init_downlink_error_feedback)")
        if fl.downlink_delta and state.bcast is None:
            raise ValueError(
                "fl.downlink_delta=True: state.bcast is missing — build "
                "the state with fl.init_round_state (or "
                "transport.downlink.init_broadcast_state)")
        params, angle_state = state.params, state.angle
        ef_state, dl_state = state.ef, state.dl_ef
        lr = _lr_at(fl, state.round)

        # ---- server -> client downlink: compress the broadcast model ----
        # The server keeps `params` as its uncompressed master copy (the
        # aggregated delta is applied to it below); every client trains
        # from the SAME dequantized reconstruction, so the three engines
        # cannot fork — the branch is upstream of all of them.
        params_srv = params
        new_dl, new_bcast = dl_state, state.bcast
        down_split = None
        if fl.downlink != "f32":
            pvec, punravel = treemath.tree_ravel(params)
            if fl.downlink_delta:
                # delta encoding: compress the model DIFF against the
                # chain head (the canonical reconstruction) — per-round
                # diffs are small, so the quant scales track them tightly.
                pvec = pvec - state.bcast.head
            if fl.downlink_error_feedback:
                # EF-SGD mirror: replay the carried broadcast residual,
                # then carry what this round's compression drops.
                pvec = pvec + dl_state
            qd = transport_mod.downlink.compress(pvec, fl.downlink)
            recon = transport_mod.downlink.decompress(qd)
            if fl.downlink_error_feedback:
                new_dl = pvec - recon
            if fl.downlink_delta:
                # publish version v = head_ver + 1 into the ring and
                # advance the chain head (recon is this version's delta
                # reconstruction D_v); every selected client pulls the
                # new head (delta-decoded or resynced), so its
                # last-pulled version moves to v.
                new_bcast = transport_mod.downlink.advance_broadcast(
                    state.bcast, recon)
                recon = new_bcast.head
                v = new_bcast.head_ver
                if fl.telemetry:
                    down_split = _down_byte_split(
                        fl, pvec.shape[0], state.bcast.ver[sel_idx], v)
                new_bcast = new_bcast._replace(
                    ver=new_bcast.ver.at[sel_idx].set(v))
            params = punravel(recon)

        deltas, losses = jax.vmap(
            lambda b: local_update(loss_fn, params, b, lr, fl.prox_mu,
                                   grad_constraint)
        )(batches)
        if delta_constraint is not None:
            deltas = delta_constraint(deltas)

        psi_avg = weighting.fedavg_weights(data_sizes)
        new_ef = ef_state

        # ---- client uplink: compress the stacked deltas to the wire ----
        if fl.transport != "f32" and wire_2d:
            # 2D (client x model) mesh: the wire is quantized per-shard in
            # blocked order (see fl_shard_map.make_round_ops_2d). The
            # flat_sharded engine quantizes INSIDE its region; the tree
            # engine consumes the identical reconstruction through the
            # blocked roundtrip region here (per-leaf reference reductions
            # then run on the dequantized tree, so "tree never reads the
            # wire buffer" still holds).
            if fl.engine == "tree":
                k = data_sizes.shape[0]
                kp = -(-k // csize) * csize
                deltas_p = jax.tree.map(lambda d: _pad_rows(d, kp), deltas)
                rt = fl_shard_map.make_blocked_roundtrip(
                    mesh, deltas_p, _derive_param_pspecs(params, mesh),
                    transport=fl.transport, group_size=fl.group_size)
                deltas = jax.tree.map(lambda d: d[:k], rt(deltas_p))
        elif fl.transport != "f32":
            flat0, unravel0 = treemath.tree_ravel_stacked(deltas)
            if fl.error_feedback:
                # EF-SGD: replay the carried residual into this round's
                # signal, then carry what quantization drops this round.
                flat0 = flat0 + ef_state[sel_idx]
            q = transport_mod.quantize(flat0, fl.transport,
                                       group_size=fl.group_size)
            if fl.error_feedback:
                new_ef = ef_state.at[sel_idx].set(
                    flat0 - transport_mod.dequantize(q))
            if fl.engine == "tree":
                # reference contract: the tree engine never reads the wire
                # buffer — dequantize back to the stacked tree and run the
                # per-leaf reference reductions on the reconstruction.
                # f32 leaves: rounding the dequantized values to a bf16
                # leaf dtype would add a second loss the flat engines
                # (which stream the wire directly) never incur.
                deltas = treemath.tree_unravel_stacked(
                    deltas, transport_mod.dequantize(q), jnp.float32)

        # (N,) 0/1 segment mask over the ravel order — ONE copy shared by
        # both flat engines (the tree engine masks per-leaf views instead),
        # so the angle_filter semantics cannot fork between them. The 2D
        # engine bakes the same per-leaf keep flags into its shard-local
        # blocked mask instead (treemath.blocked_segment_mask).
        maskv = None
        if fl.engine != "tree" and angle_pred and not wire_2d:
            maskv = treemath.segment_mask(params,
                                          angle_keep_list(params, angle_pred))

        if fl.engine == "flat_sharded" and wire_2d:
            # one shard_map region over the (client x model) tile grid:
            # per-tile shard-local ravel + quantize + fused kernels, stat
            # psums over both axes, replicated Eq.9 + Gompertz, aggregate
            # psum over the client axis only — model-sharded leaves come
            # back still sharded (no full-N gather anywhere).
            k = data_sizes.shape[0]
            kp = -(-k // csize) * csize
            deltas_p = jax.tree.map(lambda d: _pad_rows(d, kp), deltas)
            keep = (angle_keep_list(params, angle_pred)
                    if angle_pred else None)
            round_ops_2d = fl_shard_map.make_round_ops_2d(
                mesh, deltas_p, _derive_param_pspecs(params, mesh),
                alpha=fl.alpha, method=fl.method,
                interpret=_resolve_interpret(fl), transport=fl.transport,
                group_size=fl.group_size, keep=keep)
            # padded rows: zero deltas, zero data size -> -inf softmax
            # logit -> exactly zero weight and zero stats contribution.
            g_avg, dots, sqs, sqg, delta, theta, _, w = round_ops_2d(
                deltas_p, _pad_rows(psi_avg, kp),
                _pad_rows(angle_state.smoothed[sel_idx], kp),
                _pad_rows(angle_state.count[sel_idx], kp),
                _pad_rows(data_sizes, kp))
            dots, sqs = dots[:k], sqs[:k]
            theta, w = theta[:k], w[:k]
            # f32 in-region accumulate, ONE cast to the param leaf dtype —
            # the same rounding schedule as the 1D engines' unravel.
            delta = jax.tree.map(lambda d, p: d.astype(p.dtype), delta,
                                 params)
        elif fl.engine == "flat_sharded":
            # the WHOLE round is one shard_map call (stats psums ->
            # replicated Eq.9 + Gompertz weighting -> aggregate psum):
            # rows sharded over ("pod","data"), per-shard fused kernels.
            if fl.transport == "f32":
                flat, unravel = treemath.tree_ravel_stacked(deltas)
                values, scales = flat, None
                n_logical = flat.shape[1]
            else:
                values, scales, unravel = q.values, q.scales, unravel0
                # int4 packs two params per byte: the wire buffer width is
                # NOT the logical width the mask/g vectors live in.
                n_logical = flat0.shape[1]
            k = values.shape[0]
            kp = -(-k // csize) * csize  # pad the client axis to the mesh
            values = jax.lax.with_sharding_constraint(
                _pad_rows(values, kp), row_sharding)
            mvec = (maskv if maskv is not None
                    else jnp.ones((n_logical,), jnp.float32))
            wire = (values,) if scales is None else (
                values, jax.lax.with_sharding_constraint(
                    _pad_rows(scales, kp, 1.0), row_sharding))
            # padded rows: zero deltas, zero data size -> -inf softmax
            # logit -> exactly zero weight and zero stats contribution.
            g_flat, dots, sqs, sqg, delta_flat, theta, _, w = round_ops(
                *wire, _pad_rows(psi_avg, kp), mvec,
                _pad_rows(angle_state.smoothed[sel_idx], kp),
                _pad_rows(angle_state.count[sel_idx], kp),
                _pad_rows(data_sizes, kp))
            dots, sqs = dots[:k], sqs[:k]
            theta, w = theta[:k], w[:k]
            g_avg = unravel(g_flat, jnp.float32)
            delta = unravel(delta_flat)
        elif fl.engine == "flat":
            # single (K, N) ravel; stats + both aggregations are fused
            # single-HBM-pass kernels over the contiguous buffer
            # (chunked over the client axis, so any K fits the VMEM
            # envelope). Quantized wire buffers flow through the
            # fused-dequant kernel variants untouched.
            interpret = _resolve_interpret(fl)
            if fl.transport == "f32":
                flat, unravel = treemath.tree_ravel_stacked(deltas)
                wire_x, wire_s = flat, None
            else:
                unravel = unravel0
                wire_x, wire_s = q.values, q.scales

            def agg_wire(wvec):
                if wire_s is None:
                    return weighted_agg_mod.weighted_agg(
                        wvec, wire_x, interpret=interpret,
                        out_dtype=jnp.float32)
                if fl.transport == "int4":
                    return weighted_agg_mod.weighted_agg_q4(
                        wvec, wire_x, wire_s, n=flat0.shape[1],
                        group_size=fl.group_size, interpret=interpret)
                return weighted_agg_mod.weighted_agg_q(
                    wvec, wire_x, wire_s, interpret=interpret)

            g_flat = agg_wire(psi_avg)
            if wire_s is None:
                dots, sqs, sqg = round_stats_mod.round_stats(
                    wire_x, g_flat, maskv, interpret=interpret)
            elif fl.transport == "int4":
                dots, sqs, sqg = round_stats_mod.round_stats_q4(
                    wire_x, wire_s, g_flat, maskv,
                    group_size=fl.group_size, interpret=interpret)
            else:
                dots, sqs, sqg = round_stats_mod.round_stats_q(
                    wire_x, wire_s, g_flat, maskv, interpret=interpret)
            g_avg = unravel(g_flat, jnp.float32)
            theta = weighting.instantaneous_angle(dots, sqs, sqg)
        else:
            angle_mask = (build_angle_mask(params, angle_pred)
                          if angle_pred else None)
            # f32: rounding g to the (possibly bf16) leaf dtype before
            # the stats would lose the angle signal and diverge from the
            # flat engine; also matches init_prev_delta's f32 threading.
            g_avg = treemath.tree_weighted_sum(deltas, psi_avg,
                                               jnp.float32)
            d_view = angle_mask(deltas) if angle_mask else deltas
            g_view = angle_mask(g_avg) if angle_mask else g_avg
            dots = treemath.tree_vdot_batched(d_view, g_view)
            sqs = treemath.tree_sqnorm_batched(d_view)
            sqg = treemath.tree_sqnorm(g_view)
            theta = weighting.instantaneous_angle(dots, sqs, sqg)

        # Eq. 9 scatter — ONE copy for all engines (flat_sharded computed
        # the same float ops in-region for its weighting; this scatter is
        # its state bookkeeping and must stay op-identical).
        new_state = _scatter_angles(angle_state, sel_idx, theta)
        theta_sm = new_state.smoothed[sel_idx]
        if fl.engine != "flat_sharded":
            if fl.method == "fedadp":
                w = weighting.fedadp_weights(theta_sm, data_sizes, fl.alpha)
            else:  # fedavg / fedprox aggregate by data size
                w = psi_avg
            if fl.engine == "flat":
                # fedavg/fedprox aggregate with w == psi_avg: reuse g_flat
                # rather than re-streaming the buffer (no Pallas CSE)
                delta_flat = g_flat if fl.method != "fedadp" else agg_wire(w)
                delta = unravel(delta_flat)
            else:
                # f32 accumulate, ONE cast to the param leaf dtype — same
                # rounding schedule as the flat engines' unravel, and it
                # keeps params at their dtype when the transport path
                # reconstructed the deltas as f32 leaves.
                delta = jax.tree.map(
                    lambda d, p: d.astype(p.dtype),
                    treemath.tree_weighted_sum(deltas, w, jnp.float32),
                    params)
        # the delta lands on the server's uncompressed master params — the
        # downlink reconstruction is what the CLIENTS trained from.
        new_params = treemath.tree_add(params_srv, delta)

        # Fig.7 divergence: (1/K) sum_i ||dF - dF_i|| with dF ~ -delta/lr
        div = jnp.mean(jnp.sqrt(jnp.maximum(sqs - 2 * dots + sqg, 0.0))) / lr
        metrics = {
            "loss": jnp.mean(losses), "theta": theta, "theta_smoothed": theta_sm,
            "weights": w, "divergence": div, "lr": lr,
            "cos": jnp.cos(theta),
            "expected_contribution": weighting.expected_contribution(w, jnp.cos(theta)),
        }
        if fl.telemetry:
            metrics.update(_telemetry_metrics(fl, params, sel_idx, w,
                                              down_split=down_split))
        return state._replace(
            params=new_params, angle=new_state, prev_delta=g_avg,
            ef=new_ef, dl_ef=new_dl, bcast=new_bcast,
            round=state.round + 1,
        ), metrics

    return round_fn


def _make_buffered_round(loss_fn, fl: FLConfig, delta_constraint,
                         angle_pred=None, grad_constraint=None, mesh=None,
                         arrival_fn=None):
    """The buffered-async server tick (aggregation="buffered").

    Same `round_fn(state, batches, sel_idx, data_sizes)` signature as the
    lockstep round, but one call is one server TICK, not one model
    version: the K candidate clients pull the current broadcast, train,
    and their reports are ADMITTED into the free slots of the K-row
    report buffer (`state.buf`, see core.buffer) with simulated arrival
    delays; the params advance only on ticks where at least `buffer_m`
    of the in-flight reports have LANDED. `state.round` counts ticks (it
    still drives the lr schedule and `arrival_fn(tick)` indexing); a
    report's staleness `age` counts flushes — actual model versions —
    between its pull and its aggregation.

    Everything is mask-based so the tick is shape-static: non-admitted
    candidates are computed and discarded (occupied slots, busy clients,
    in-transit dropouts), non-landed rows get exactly zero aggregation
    weight, and a non-flush tick applies `jnp.where(do_flush, ...)`
    no-ops to params/angles/prev_delta. With buffer_m == K and no
    stragglers/dropouts every tick admits, lands, and flushes the whole
    cohort at age 0, and each masked op reduces bit-exactly to its sync
    counterpart — that equivalence is pinned per engine by
    tests/test_buffered.py.
    """
    stochastic = (arrival_fn is None
                  and (fl.straggle_prob > 0 or fl.dropout_prob > 0))
    m_flush = fl.buffer_m if fl.buffer_m > 0 else fl.clients_per_round
    flush_ops = None
    if fl.engine == "flat_sharded":
        # wire compression happens at admission (the buffer holds
        # dequantized f32 rows), so the flush region never needs scales.
        flush_ops = fl_shard_map.make_buffered_flush_ops(
            mesh, alpha=fl.alpha, method=fl.method, beta=fl.staleness_beta,
            interpret=_resolve_interpret(fl))
        row_sharding = fl_shard_map.flat_client_sharding(mesh)
        csize = fl_shard_map.client_axis_size(mesh)
        # 2D (client x model) mesh: the flush region also tiles the
        # buffer's COLUMNS over the model axis (admission stays the
        # global f32 buffer — only the flush's layout changes). Columns
        # are zero-padded to a multiple of the model-axis size and the
        # model-sharded outputs sliced back below.
        msize = fl_shard_map.model_axis_size(mesh)
        if msize > 1:
            from jax.sharding import NamedSharding, PartitionSpec

            caxes = fl_shard_map._client_axes(mesh)
            row_sharding = NamedSharding(
                mesh, PartitionSpec(
                    caxes if len(caxes) > 1 else caxes[0],
                    fl_shard_map.MODEL_AXIS))

    def round_fn(state: RoundState, batches, sel_idx, data_sizes):
        if state.buf is None:
            raise ValueError(
                "fl.aggregation='buffered': state.buf is missing — build "
                "the state with fl.init_round_state (or "
                "core.buffer.init_report_buffer)")
        if fl.error_feedback and state.ef is None:
            raise ValueError(
                "fl.error_feedback=True: state.ef is missing — build the "
                "state with fl.init_round_state (or "
                "transport.init_error_feedback)")
        if fl.downlink_error_feedback and state.dl_ef is None:
            raise ValueError(
                "fl.downlink_error_feedback=True: state.dl_ef is missing "
                "— build the state with fl.init_round_state (or "
                "transport.downlink.init_downlink_error_feedback)")
        if fl.downlink_delta and state.bcast is None:
            raise ValueError(
                "fl.downlink_delta=True: state.bcast is missing — build "
                "the state with fl.init_round_state (or "
                "transport.downlink.init_broadcast_state)")
        params, angle_state = state.params, state.angle
        ef_state, dl_state = state.ef, state.dl_ef
        lr = _lr_at(fl, state.round)
        k = fl.clients_per_round

        # ---- arrival injection: when do this tick's reports land? ----
        # RNG discipline: the key is only consumed when the config is
        # actually stochastic, so the deterministic case threads
        # state.rng untouched exactly like the sync round (this is part
        # of the bit-exact sync-equivalence contract).
        new_rng = state.rng
        if arrival_fn is not None:
            delay, drop = arrival_fn(state.round)
            delay = jnp.asarray(delay, jnp.int32)
            drop = jnp.asarray(drop, bool)
        elif stochastic:
            new_rng, k_arr = jax.random.split(state.rng)
            delay, drop = buffer_mod.draw_arrivals(
                k_arr, k, fl.straggle_prob, fl.straggle_max,
                fl.dropout_prob)
        else:
            delay = jnp.zeros((k,), jnp.int32)
            drop = jnp.zeros((k,), bool)

        # ---- server -> client downlink (identical to the sync round:
        # candidates pull the CURRENT broadcast every tick, so the
        # downlink EF / broadcast-chain bookkeeping advances per tick;
        # the per-client version rows move only for ADMITTED candidates,
        # below, once the admission mask is known — admission is when a
        # pull actually happens in the simulation, which is what fixes a
        # buffered client's decode base at admission time) ----
        params_srv = params
        new_dl, new_bcast = dl_state, state.bcast
        bcast_v, n_ravel = None, 0
        if fl.downlink != "f32":
            pvec, punravel = treemath.tree_ravel(params)
            n_ravel = pvec.shape[0]
            if fl.downlink_delta:
                pvec = pvec - state.bcast.head
            if fl.downlink_error_feedback:
                pvec = pvec + dl_state
            qd = transport_mod.downlink.compress(pvec, fl.downlink)
            recon = transport_mod.downlink.decompress(qd)
            if fl.downlink_error_feedback:
                new_dl = pvec - recon
            if fl.downlink_delta:
                new_bcast = transport_mod.downlink.advance_broadcast(
                    state.bcast, recon)
                recon = new_bcast.head
                bcast_v = new_bcast.head_ver
            params = punravel(recon)

        # ---- candidate local updates (all K slots compute; admission
        # masks decide whose report actually enters the buffer) ----
        deltas, losses = jax.vmap(
            lambda b: local_update(loss_fn, params, b, lr, fl.prox_mu,
                                   grad_constraint)
        )(batches)
        if delta_constraint is not None:
            deltas = delta_constraint(deltas)

        # a free slot admits its candidate unless the client already has
        # a report in flight (full participation re-offers everyone) or
        # the report drops in transit (the slot stays free — liveness
        # never waits on a timeout).
        busy = buffer_mod.population_busy(state.buf, fl.num_clients)
        admit = state.buf.free & ~busy[sel_idx] & ~drop

        # admitted candidates actually pulled this tick's broadcast:
        # their decode base — and so their last-pulled version — is
        # fixed at admission time; busy/dropped candidates never pulled
        # and are neither version-advanced nor charged downlink bytes.
        down_split = None
        if fl.downlink_delta:
            if fl.telemetry:
                down_split = _down_byte_split(
                    fl, n_ravel, state.bcast.ver[sel_idx], bcast_v,
                    pulled=admit)
            new_bcast = new_bcast._replace(
                ver=new_bcast.ver.at[sel_idx].set(
                    jnp.where(admit, bcast_v, new_bcast.ver[sel_idx])))

        # ---- client uplink: compress to the wire, buffer the f32
        # reconstruction (the tree engine never reads the wire, and rows
        # must survive across ticks independent of the transport) ----
        flat0, unravel0 = treemath.tree_ravel_stacked(deltas)
        new_ef = ef_state
        if fl.transport == "f32":
            rows = flat0
        else:
            if fl.error_feedback:
                flat0 = flat0 + ef_state[sel_idx]
            q = transport_mod.quantize(flat0, fl.transport,
                                       group_size=fl.group_size)
            rows = transport_mod.dequantize(q)
            if fl.error_feedback:
                # the residual of a non-admitted report stays carried —
                # that report never shipped, so nothing was dropped yet.
                new_ef = ef_state.at[sel_idx].set(
                    jnp.where(admit[:, None], flat0 - rows,
                              ef_state[sel_idx]))
        buf = buffer_mod.admit(state.buf, admit, rows, sel_idx,
                               data_sizes, delay)

        landed = buffer_mod.landed_mask(buf)
        num_landed = jnp.sum(landed.astype(jnp.int32))
        do_flush = num_landed >= m_flush

        # staleness-discounted FedAvg weights over the landed rows — the
        # angle-reference global delta g, exactly psi_avg when every row
        # landed at age 0.
        psi_b = weighting.buffered_fedavg_weights(
            buf.sizes, buf.age, landed, fl.staleness_beta)

        maskv = None
        if fl.engine != "tree" and angle_pred:
            maskv = treemath.segment_mask(params,
                                          angle_keep_list(params, angle_pred))

        if fl.engine == "flat_sharded":
            # same single-region schedule as the sync round, over the f32
            # report rows; padded rows land False -> exactly zero weight.
            kp = -(-k // csize) * csize
            n = buf.data.shape[1]
            npad = -(-n // msize) * msize
            values = _pad_rows(buf.data, kp)
            mvec = (maskv if maskv is not None
                    else jnp.ones((n,), jnp.float32))
            if npad != n:
                # zero columns: zero in both rows and aggregate, so every
                # stat contribution is exactly zero; sliced off below.
                values = jnp.pad(values, ((0, 0), (0, npad - n)))
                mvec = jnp.pad(mvec, (0, npad - n))
            values = jax.lax.with_sharding_constraint(values, row_sharding)
            g_flat, dots, sqs, sqg, delta_flat, theta, _, w = flush_ops(
                values, _pad_rows(psi_b, kp), mvec,
                _pad_rows(angle_state.smoothed[buf.slot], kp),
                _pad_rows(angle_state.count[buf.slot], kp),
                _pad_rows(buf.sizes, kp, 1.0), _pad_rows(buf.age, kp),
                _pad_rows(landed, kp, False))
            dots, sqs = dots[:k], sqs[:k]
            theta, w = theta[:k], w[:k]
            if npad != n:
                g_flat = g_flat[:n]
                delta_flat = delta_flat[:n]
            g_avg = unravel0(g_flat, jnp.float32)
            delta = unravel0(delta_flat)
        elif fl.engine == "flat":
            interpret = _resolve_interpret(fl)
            g_flat = weighted_agg_mod.weighted_agg(
                psi_b, buf.data, interpret=interpret, out_dtype=jnp.float32)
            dots, sqs, sqg = round_stats_mod.round_stats(
                buf.data, g_flat, maskv, interpret=interpret)
            g_avg = unravel0(g_flat, jnp.float32)
            theta = weighting.instantaneous_angle(dots, sqs, sqg)
        else:
            deltas_b = treemath.tree_unravel_stacked(deltas, buf.data,
                                                     jnp.float32)
            angle_mask = (build_angle_mask(params, angle_pred)
                          if angle_pred else None)
            g_avg = treemath.tree_weighted_sum(deltas_b, psi_b, jnp.float32)
            d_view = angle_mask(deltas_b) if angle_mask else deltas_b
            g_view = angle_mask(g_avg) if angle_mask else g_avg
            dots = treemath.tree_vdot_batched(d_view, g_view)
            sqs = treemath.tree_sqnorm_batched(d_view)
            sqg = treemath.tree_sqnorm(g_view)
            theta = weighting.instantaneous_angle(dots, sqs, sqg)

        # Eq. 9 over the LANDED reports only, applied only on flush ticks
        # (both masks reduce to the sync scatter when everything landed).
        ang_flushed = _scatter_angles_masked(angle_state, buf.slot, theta,
                                             landed)
        new_angle = jax.tree.map(lambda a, b: jnp.where(do_flush, a, b),
                                 ang_flushed, angle_state)
        theta_sm = new_angle.smoothed[buf.slot]
        if fl.engine != "flat_sharded":
            if fl.method == "fedadp":
                w = weighting.buffered_fedadp_weights(
                    theta_sm, buf.sizes, buf.age, landed, fl.alpha,
                    fl.staleness_beta)
            else:
                w = psi_b
            if fl.engine == "flat":
                delta_flat = (g_flat if fl.method != "fedadp" else
                              weighted_agg_mod.weighted_agg(
                                  w, buf.data, interpret=interpret,
                                  out_dtype=jnp.float32))
                delta = unravel0(delta_flat)
            else:
                delta = jax.tree.map(
                    lambda d, p: d.astype(p.dtype),
                    treemath.tree_weighted_sum(deltas_b, w, jnp.float32),
                    params)

        # flush: apply the aggregated delta to the master params — or, on
        # a non-flush tick, carry everything unchanged (where no-ops).
        new_params = jax.tree.map(
            lambda a, b: jnp.where(do_flush, a, b),
            treemath.tree_add(params_srv, delta), params_srv)
        new_prev = jax.tree.map(lambda a, b: jnp.where(do_flush, a, b),
                                g_avg, state.prev_delta)
        final_buf = buffer_mod.advance(buf, landed, do_flush)

        nl_f = jnp.maximum(num_landed.astype(jnp.float32), 1.0)
        div = jnp.sum(jnp.where(
            landed, jnp.sqrt(jnp.maximum(sqs - 2 * dots + sqg, 0.0)),
            0.0)) / nl_f / lr
        metrics = {
            "loss": jnp.mean(losses), "theta": theta,
            "theta_smoothed": theta_sm, "weights": w, "divergence": div,
            "lr": lr, "cos": jnp.cos(theta),
            "expected_contribution": weighting.expected_contribution(
                w, jnp.cos(theta)),
            "flushed": do_flush.astype(jnp.int32),
            "buffer_landed": num_landed,
            "staleness": jnp.sum(jnp.where(landed, buf.age, 0)
                                 .astype(jnp.float32)) / nl_f,
        }
        if fl.telemetry:
            # attribution follows the BUFFER rows (theta/weights are
            # computed over them), not this tick's candidates; ages and
            # the landed mask are per-row, occupancy counts live slots.
            metrics.update(_telemetry_metrics(fl, params, buf.slot, w,
                                              occupied=~buf.free,
                                              down_split=down_split))
            metrics["tel/ages"] = buf.age
            metrics["tel/landed"] = landed
            metrics["tel/occupancy"] = jnp.sum((~buf.free)
                                               .astype(jnp.int32))
        return state._replace(
            params=new_params, angle=new_angle, prev_delta=new_prev,
            ef=new_ef, dl_ef=new_dl, bcast=new_bcast,
            buf=final_buf, rng=new_rng, round=state.round + 1,
        ), metrics

    return round_fn


def _make_sequential_round(loss_fn, fl: FLConfig, angle_pred=None,
                           grad_constraint=None):
    def round_fn(state: RoundState, batches, sel_idx, data_sizes):
        params, angle_state = state.params, state.angle
        prev_delta = state.prev_delta
        lr = _lr_at(fl, state.round)
        interpret = _resolve_interpret(fl)
        # one stats implementation across modes: pass-2 statistics stream
        # through the round_stats kernel as a single-row (1, N) buffer per
        # scan step, with the MoE angle filter as a flat segment mask.
        maskv = (
            treemath.segment_mask(params, angle_keep_list(params, angle_pred))
            if angle_pred else None
        )
        psi_avg = data_sizes / jnp.sum(data_sizes)
        zeros32 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

        if not fl.stale_angles:
            # ---- pass 1: global (FedAvg-weighted) delta ----
            def p1(acc, xs):
                b_i, psi_i = xs
                d_i, loss = local_update(loss_fn, params, b_i, lr, fl.prox_mu,
                                         grad_constraint)
                return treemath.tree_axpy(psi_i, d_i, acc), loss

            g_avg, losses = jax.lax.scan(p1, zeros32, (batches, psi_avg))
            g_ref = g_avg
        else:
            g_ref = prev_delta
            losses = None

        g_flat, _ = treemath.tree_ravel(g_ref)

        # ---- pass 2 (or single stale pass): stats + online weighted sum ----
        def p2(carry, xs):
            num, den, g_acc = carry
            b_i, psi_i, D_i, idx_i = xs
            d_i, loss = local_update(loss_fn, params, b_i, lr, fl.prox_mu,
                                     grad_constraint)
            d_flat, _ = treemath.tree_ravel(d_i)
            dots_i, sqs_i, sqg_i = round_stats_mod.round_stats(
                d_flat[None], g_flat, maskv, interpret=interpret)
            dot, sq = dots_i[0], sqs_i[0]
            theta_i = weighting.instantaneous_angle(dot, sq, sqg_i)
            cnt = angle_state.count[idx_i].astype(jnp.float32) + 1.0
            sm = ((cnt - 1.0) * angle_state.smoothed[idx_i] + theta_i) / cnt
            if fl.method == "fedadp":
                w_i = D_i * jnp.exp(weighting.gompertz(sm, fl.alpha))
            else:
                w_i = D_i
            num = treemath.tree_axpy(w_i, d_i, num)
            g_acc = treemath.tree_axpy(psi_i, d_i, g_acc)
            return (num, den + w_i, g_acc), (theta_i, sm, dot, sq, sqg_i, loss)

        (num, den, g_acc), ys = jax.lax.scan(
            p2, (zeros32, jnp.zeros((), jnp.float32), zeros32),
            (batches, psi_avg, data_sizes.astype(jnp.float32), sel_idx),
        )
        theta, theta_sm, dots, sqs, sqgs, losses2 = ys
        delta = treemath.tree_scale(num, 1.0 / jnp.maximum(den, 1e-12))
        new_params = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype), params, delta
        )
        new_state = _scatter_angles(angle_state, sel_idx, theta)
        w = (
            weighting.fedadp_weights(theta_sm, data_sizes, fl.alpha)
            if fl.method == "fedadp"
            else psi_avg
        )
        div = jnp.mean(jnp.sqrt(jnp.maximum(sqs - 2 * dots + sqgs, 0.0))) / lr
        metrics = {
            "loss": jnp.mean(losses if losses is not None else losses2),
            "theta": theta, "theta_smoothed": theta_sm, "weights": w,
            "divergence": div, "lr": lr, "cos": jnp.cos(theta),
            "expected_contribution": weighting.expected_contribution(w, jnp.cos(theta)),
        }
        if fl.telemetry:
            metrics.update(_telemetry_metrics(fl, params, sel_idx, w))
        return state._replace(
            params=new_params, angle=new_state, prev_delta=g_acc,
            round=state.round + 1,
        ), metrics

    return round_fn


def init_prev_delta(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
