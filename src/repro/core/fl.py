"""Federated round engines: FedAdp / FedAvg as one compiled program.

Two execution modes (DESIGN.md §6):

* ``parallel`` — the K participating clients are vmapped; on a mesh the
  client axis is sharded over ("pod", "data"). Per-client deltas are
  materialized stacked (K, ...), angles are batched reductions, and the
  weighted aggregation is one collective contraction over the client axis.
  This is the faithful high-throughput path for models that fit K-way.

* ``sequential`` — one model copy (FSDP-shardable), clients advanced by
  `lax.scan`. FedAdp needs the round's global gradient *before* weighting,
  so the exact variant runs TWO passes (local training recomputed in pass
  2 — compute x2, memory x1/K). The key identity making two (not three)
  passes suffice: softmax weights factor as w_i = D_i e^{f(θ̃_i)} with a
  scalar denominator, so pass 2 can accumulate Σ w_i Δ_i and Σ w_i online.

  ``stale_angles=True`` is the beyond-paper one-pass variant: angles are
  measured against the *previous* round's aggregated delta (one-round
  staleness), restoring pass-1-only compute. Evaluated in EXPERIMENTS.md.

Both modes compute their angle statistics through ONE implementation —
the fused `kernels.round_stats` Pallas kernel (client-chunked, any K):
parallel flat engines feed it the stacked (K, N) buffer (optionally
client-row-sharded under shard_map), the sequential scan feeds it one
(1, N) row per client.

Angle convention: the paper defines θ_i between ∇F and ∇F_i with
∇F_i = -Δ_i/η (Alg. 1 l.9); the -1/η factors cancel in the cosine, so we
correlate deltas directly.

Round-state contract: every engine threads ONE `RoundState` pytree — the
server-side carry of a federated round (params, Eq. 9 angle state, the
previous aggregated delta, both error-feedback residuals, the previous
broadcast for delta-encoded downlinks, the device RNG key, and the round
counter). `round_fn(state, batches, sel_idx, data_sizes) -> (state,
metrics)` is the uniform signature for parallel tree/flat/flat_sharded
and the sequential scan alike, which is what lets `core.driver` fold a
whole training run into a single `lax.scan` with the state as the carry.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import transport as transport_mod
from repro.core import fl_shard_map, treemath, weighting
from repro.core.weighting import AngleState
from repro.kernels import round_stats as round_stats_mod
from repro.kernels import weighted_agg as weighted_agg_mod

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FLConfig:
    num_clients: int  # N — population size (angle-state slots)
    clients_per_round: int  # K = |S_t|
    local_steps: int  # tau
    method: str = "fedadp"  # fedadp | fedavg | fedprox
    alpha: float = weighting.DEFAULT_ALPHA
    base_lr: float = 0.01
    lr_decay: float = 0.995  # per communication round (paper Sec. V)
    mode: str = "parallel"  # parallel | sequential
    stale_angles: bool = False  # sequential one-pass variant
    # parallel-mode execution engine:
    #   "tree" — per-leaf treemath reductions (reference; keeps sharded
    #            leaves sharded, the right trade on a model-sharded mesh)
    #   "flat" — deltas raveled once into a contiguous (K, N) f32 buffer;
    #            angle stats + aggregation run as single-HBM-pass Pallas
    #            kernels (round_stats / weighted_agg). The client axis is
    #            CHUNKED inside the kernels (<= kernels.weighted_agg.K_TILE
    #            clients per VMEM tile), so any K is supported — there is
    #            no MAX_K ceiling.
    #   "flat_sharded" — the flat buffer row-sharded over the mesh client
    #            axis ("pod","data"); the WHOLE round (per-shard kernel
    #            calls, stat psums, replicated weighting, aggregate psum)
    #            is one shard_map region via fl_shard_map.make_round_ops.
    #            Requires passing `mesh=` to make_round_fn; any
    #            clients_per_round works (K % shards != 0 zero-pads the
    #            client axis — padded rows get exactly zero weight).
    # The sequential mode's pass-2 statistics also stream through the
    # round_stats kernel (K=1 rows against the raveled global delta), so
    # all modes share one stats implementation.
    engine: str = "tree"  # tree | flat | flat_sharded
    # Delta transport — the client-uplink wire format (repro.transport):
    #   "f32"  — reference wire, deltas ship unmodified.
    #   "bf16" — 2 bytes/param; the flat engines read the bf16 buffer
    #            directly (the kernels' in-VMEM astype IS the dequant).
    #   "int8" — 1 byte/param + one f32 scale per (client, kernel chunk);
    #            the flat engines run the fused in-register-dequant kernels
    #            (round_stats_q / weighted_agg_q) so stats + aggregation
    #            stay one HBM pass over ~4x fewer bytes. The tree engine
    #            NEVER reads quantized buffers: it dequantizes back to the
    #            stacked tree and runs the per-leaf reference reductions.
    #   "int4" — two params per byte (packed nibble pairs) + one f32 scale
    #            per (client, `group_size` elements); the flat engines run
    #            the grouped-scale fused kernels (round_stats_q4 /
    #            weighted_agg_q4) — one HBM pass over ~8x fewer bytes.
    transport: str = "f32"  # f32 | bf16 | int8 | int4
    # int4 scale-group width: one f32 dequant scale per `group_size`
    # consecutive elements of a client's flat delta row. Must be even and
    # divide kernels' CHUNK = ROWS*LANE = 16384 (so a packed byte never
    # straddles a group and kernel tiles cover whole groups); smaller
    # groups track local magnitude better at 4/group_size bytes/param of
    # side data. Ignored by the other transports (int8 stays per-chunk).
    group_size: int = transport_mod.GROUP_SIZE
    # Server->client broadcast (downlink) wire format
    # (repro.transport.downlink): "f32" is the reference broadcast (the
    # round is then byte-identical upstream of this option); "bf16"/"int8"
    # compress the global model once per round and EVERY engine trains its
    # clients from the same dequantized reconstruction, so engine parity
    # is preserved by construction. The server always applies the
    # aggregated delta to its own uncompressed master params.
    downlink: str = "f32"  # f32 | bf16 | int8
    # Delta-encode the broadcast: ship the quantized model DIFF against
    # the previous round's reconstructed broadcast instead of the full
    # model (`transport.downlink.delta_compress` on the raveled (1, N)
    # diff). Per-round deltas are orders of magnitude smaller than the
    # params themselves, so the same wire format reconstructs them far
    # more accurately (the int8 scale tracks the diff's absmax, not the
    # model's). Requires downlink != "f32" (an exact broadcast has no
    # reason to diff) and threads `RoundState.prev_broadcast` — the (N,)
    # reconstruction every client saw last round, zeros at init so round
    # 0 broadcasts the full model. Composes with downlink_error_feedback
    # (the EF residual rides on the diff before compression).
    downlink_delta: bool = False
    # Carry the per-client quantization residual across rounds (EF-SGD) so
    # the compressed angle statistics stay unbiased over time. Requires
    # transport != "f32" and parallel mode; the residual lives in
    # `RoundState.ef` — a (num_clients, N) f32 array
    # (transport.init_error_feedback) that `init_round_state` allocates
    # and round_fn updates in place of the old trailing ef_state output.
    error_feedback: bool = False
    # Server-side EF mirror for the downlink: carry the broadcast residual
    # params - dequant(quant(params)) across rounds so the model the
    # clients see is unbiased over time. Requires downlink != "f32"; the
    # residual lives in `RoundState.dl_ef` — an (N,) f32 vector
    # (transport.downlink.init_downlink_error_feedback) allocated by
    # `init_round_state` and updated by round_fn each round.
    downlink_error_feedback: bool = False
    # Pallas interpret mode for engine="flat": None = auto (interpret
    # everywhere except a real TPU backend), or force True/False.
    interpret: Optional[bool] = None
    # beyond-paper: restrict angle statistics to non-expert parameters —
    # MoE routing makes expert deltas sparse/noisy, polluting the cosine.
    angle_filter: str = "all"  # all | dense_only
    # fedprox (Li et al. 2018) baseline: mu/2 ||w - w_global||^2 proximal term
    prox_mu: float = 0.0


class RoundState(NamedTuple):
    """The unified server-side carry of a federated round.

    One pytree threaded identically through every engine (tree / flat /
    flat_sharded / sequential): `round_fn(state, batches, sel_idx,
    data_sizes) -> (state, metrics)`. Because the whole carry is a single
    pytree with a STATIC structure, `core.driver` can scan it over rounds
    (`lax.scan`) and donate its buffers so params/EF update in place.

    Optional fields are None when the matching FLConfig flag is off —
    None is an empty pytree, so the carry structure stays fixed per
    config and the scan carry never changes shape.
    """

    params: PyTree  # the server's uncompressed master model
    angle: AngleState  # Eq. 9 smoothed angles + participation counts
    prev_delta: PyTree  # last aggregated global delta, f32 leaves
    #   (the stale_angles reference; threaded untouched otherwise)
    ef: Optional[jax.Array] = None  # (num_clients, N) uplink EF residual
    dl_ef: Optional[jax.Array] = None  # (N,) downlink EF residual
    prev_broadcast: Optional[jax.Array] = None  # (N,) last broadcast
    #   reconstruction (downlink_delta; zeros -> round 0 ships the model)
    rng: Optional[jax.Array] = None  # device PRNG key — owned by the
    #   data/selection pipeline (core.driver); round_fn threads it as-is
    round: Any = 0  # i32 round counter (drives the lr schedule)


def param_count(params: PyTree) -> int:
    """Total scalar parameter count N (the flat-buffer width)."""
    return sum(math.prod(p.shape) for p in jax.tree.leaves(params))


def init_round_state(fl: FLConfig, params: PyTree,
                     seed: "int | jax.Array" = 0) -> RoundState:
    """Fresh RoundState for `params` under `fl`.

    Allocates exactly the optional buffers the config calls for (uplink
    EF rows, downlink EF vector, previous-broadcast vector) so the state
    structure is a pure function of the config. `seed` is an int (a new
    `jax.random.key` is made) or an existing PRNG key array.
    """
    n = param_count(params)
    rng = seed if isinstance(seed, jax.Array) else jax.random.key(seed)
    return RoundState(
        params=params,
        angle=AngleState.init(fl.num_clients),
        prev_delta=init_prev_delta(params),
        ef=(transport_mod.init_error_feedback(fl.num_clients, n)
            if fl.error_feedback else None),
        dl_ef=(transport_mod.downlink.init_downlink_error_feedback(n)
               if fl.downlink_error_feedback else None),
        prev_broadcast=(transport_mod.downlink.init_prev_broadcast(n)
                        if fl.downlink_delta else None),
        rng=rng,
        round=jnp.int32(0),
    )


def state_to_tree(state: RoundState) -> dict:
    """RoundState -> a nested dict `checkpoint.io.save` can round-trip.

    Field-for-field: NamedTuples become dicts, optional fields stay None
    (the io layer writes `__none__` sentinels so the structure survives),
    and the typed PRNG key ships as-is (io serializes it via
    `jax.random.key_data` + an impl tag). `state_from_tree` is the
    inverse."""
    return {
        "params": state.params,
        "angle": {"smoothed": state.angle.smoothed,
                  "count": state.angle.count},
        "prev_delta": state.prev_delta,
        "ef": state.ef,
        "dl_ef": state.dl_ef,
        "prev_broadcast": state.prev_broadcast,
        "rng": state.rng,
        "round": state.round,
    }


def _resize_rows(a: jax.Array, k_new: int) -> jax.Array:
    """Truncate / zero-pad axis 0 to `k_new` rows (elastic-K restore)."""
    k_old = a.shape[0]
    if k_new == k_old:
        return a
    if k_new < k_old:
        return a[:k_new]
    pad = jnp.zeros((k_new - k_old,) + a.shape[1:], a.dtype)
    return jnp.concatenate([a, pad])


def state_from_tree(cfg: FLConfig, tree: dict) -> RoundState:
    """Rebuild a RoundState from `state_to_tree`'s dict under `cfg`.

    The restored state's pytree structure is the CONFIG's — each optional
    field (ef / dl_ef / prev_broadcast) must be present exactly when the
    matching flag is on, and every leaf is validated (shape AND dtype)
    against `init_round_state`'s template, so a checkpoint from a
    different model or an incompatible config fails loudly instead of
    mis-resuming.

    Elastic-K: when `cfg.num_clients` differs from the checkpoint's, the
    per-client state is re-sized — AngleState rows and uplink-EF rows are
    truncated (shrink) or zero-padded (grow). New clients therefore start
    exactly like round-0 clients: zero EF residual, unseen angle
    (smoothed=0, count=0). Departed clients' slots are dropped. The
    per-model vectors (dl_ef, prev_broadcast) and params are K-independent
    and restore bit-exactly.

    Old-style raw `uint32` PRNG keys (pre-typed-key checkpoints) are
    wrapped back into a typed key via `jax.random.wrap_key_data` with the
    default impl.
    """
    missing = [k for k in ("params", "angle", "prev_delta", "rng", "round")
               if tree.get(k) is None]
    if missing:
        raise ValueError(
            f"checkpoint tree lacks required RoundState fields {missing} "
            "— was it written by fl.state_to_tree?")
    for name, flag, want in (
            ("ef", "error_feedback", cfg.error_feedback),
            ("dl_ef", "downlink_error_feedback", cfg.downlink_error_feedback),
            ("prev_broadcast", "downlink_delta", cfg.downlink_delta)):
        have = tree.get(name) is not None
        if want and not have:
            raise ValueError(
                f"cfg.{flag}=True but the checkpoint has no {name!r} — it "
                "was written under a config with the feature off; restore "
                "with a matching config (or re-init that buffer yourself)")
        if have and not want:
            raise ValueError(
                f"checkpoint carries {name!r} but cfg.{flag}=False — "
                "dropping a live residual would silently change the run; "
                "restore with a matching config")

    params = tree["params"]
    rng = tree["rng"]
    if not jax.dtypes.issubdtype(rng.dtype, jax.dtypes.prng_key):
        rng = jax.random.wrap_key_data(jnp.asarray(rng, jnp.uint32))
    angle = AngleState(
        smoothed=_resize_rows(jnp.asarray(tree["angle"]["smoothed"],
                                          jnp.float32), cfg.num_clients),
        count=_resize_rows(jnp.asarray(tree["angle"]["count"], jnp.int32),
                           cfg.num_clients),
    )
    ef = tree.get("ef")
    if ef is not None:
        ef = _resize_rows(ef, cfg.num_clients)
    state = RoundState(
        params=params, angle=angle, prev_delta=tree["prev_delta"],
        ef=ef, dl_ef=tree.get("dl_ef"),
        prev_broadcast=tree.get("prev_broadcast"),
        rng=rng, round=jnp.asarray(tree["round"], jnp.int32),
    )

    # validate against the config's own allocation: same pytree structure,
    # and shape/dtype equality on every leaf.
    p_sds = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    template = jax.eval_shape(lambda p: init_round_state(cfg, p), p_sds)
    got_def = jax.tree.structure(state)
    want_def = jax.tree.structure(template)
    if got_def != want_def:
        raise ValueError(
            "restored RoundState structure does not match "
            f"init_round_state({cfg.num_clients} clients): got {got_def}, "
            f"want {want_def}")
    got = jax.tree_util.tree_flatten_with_path(state)[0]
    want = jax.tree.leaves(template)
    for (path, leaf), ref in zip(got, want):
        name = jax.tree_util.keystr(path)
        if leaf.shape != ref.shape or leaf.dtype != ref.dtype:
            raise ValueError(
                f"checkpoint leaf {name} has shape {leaf.shape} dtype "
                f"{leaf.dtype}, but the config allocates {ref.shape} "
                f"{ref.dtype} — wrong model or incompatible config")
    return state


def local_update(loss_fn: Callable, params: PyTree, batches: PyTree, lr,
                 prox_mu: float = 0.0, grad_constraint: Optional[Callable] = None):
    """tau steps of SGD on one client. batches: leaves (tau, B, ...).

    prox_mu > 0 adds FedProx's proximal term mu/2 ||w - w(t-1)||^2 against
    the round's starting params (Li et al. 2018 — baseline for comparison).
    grad_constraint re-shards per-step gradients (e.g. onto the FSDP param
    spec so GSPMD reduce-scatters batch-partial grads instead of
    all-reducing the full tree — §Perf collective-term optimization).
    Returns (delta, mean_loss)."""

    if prox_mu > 0.0:
        base = loss_fn

        def loss_fn(p, b):  # noqa: F811 — intentional wrap
            prox = treemath.tree_sqnorm(treemath.tree_sub(p, params))
            return base(p, b) + 0.5 * prox_mu * prox

    def step(p, b):
        loss, g = jax.value_and_grad(loss_fn)(p, b)
        if grad_constraint is not None:
            g = grad_constraint(g)
        return treemath.tree_axpy(-lr, g, p), loss

    p_fin, losses = jax.lax.scan(step, params, batches)
    return treemath.tree_sub(p_fin, params), jnp.mean(losses)


def angle_keep_list(params: PyTree, pred: Callable) -> list:
    """One bool per leaf (flatten order): does `pred(path_keys, leaf)` keep it?"""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    keep = []
    for path, leaf in flat:
        keys = tuple(getattr(k, "key", getattr(k, "name", "")) for k in path)
        keep.append(bool(pred(keys, leaf)))
    return keep


def build_angle_mask(params: PyTree, pred: Callable) -> Callable:
    """Angle-statistics leaf filter, decided ONCE on the param tree.

    `pred(path_keys, param_leaf) -> keep?` is evaluated against the model's
    params; the returned mask then filters any tree with the same flatten
    order (params, deltas, or K-stacked deltas) down to the kept leaves —
    a list, which is itself a pytree treemath reductions accept.
    """
    keep = angle_keep_list(params, pred)

    def mask(tree):
        leaves = jax.tree_util.tree_leaves(tree)
        assert len(leaves) == len(keep), "mask/tree flatten-order mismatch"
        return [l for l, k in zip(leaves, keep) if k]

    return mask


def moe_dense_only_pred(keys, leaf) -> bool:
    """Keep everything except stacked routed-expert weights: leaves named
    w_gate/w_up/w_down under 'ffn' with an expert axis (rank >= 4 in the
    group-stacked param tree)."""
    return not (
        "ffn" in keys
        and keys[-1] in ("w_gate", "w_up", "w_down")
        and leaf.ndim >= 4
    )


def _scatter_angles(state: AngleState, sel_idx, theta):
    n = state.smoothed.shape[0]
    mask = jnp.zeros((n,), bool).at[sel_idx].set(True)
    theta_full = jnp.zeros((n,), jnp.float32).at[sel_idx].set(theta)
    return weighting.update_smoothed_angle(state, theta_full, mask)


def make_round_fn(loss_fn: Callable, fl: FLConfig,
                  delta_constraint: Optional[Callable] = None,
                  angle_pred: Optional[Callable] = None,
                  grad_constraint: Optional[Callable] = None,
                  mesh=None) -> Callable:
    """Build the jit-able federated round.

    round_fn(state, batches, sel_idx, data_sizes) -> (state, metrics)

    `state` is a `RoundState` (see `init_round_state`) and is threaded
    IDENTICALLY through every engine — params, Eq. 9 angle state, the
    previous aggregated delta, both EF residuals, the previous broadcast
    (downlink_delta), the device RNG key (untouched here; the driver's
    data pipeline owns it), and the round counter (incremented here; it
    drives the lr schedule). batches leaves: (K, tau, B, ...); sel_idx
    (K,) int32 population slots; data_sizes (K,) f32.
    `delta_constraint` optionally applies sharding constraints to the
    stacked deltas (parallel mode). `mesh` is required by
    engine="flat_sharded" (the client axis of the flat buffer is sharded
    over the mesh's ("pod","data") axes; K not divisible by the client
    axis is zero-padded before sharding) and ignored otherwise.

    With `fl.error_feedback` the round reads and rewrites `state.ef`
    ((num_clients, N) f32, rows of unselected clients untouched); with
    `fl.downlink_error_feedback` it reads and rewrites `state.dl_ef`
    ((N,) f32). `init_round_state` allocates both; a state missing a
    required buffer raises at call time.

    `fl.downlink` != "f32" compresses the broadcast global model before
    the clients' local updates (every engine trains from the identical
    dequantized reconstruction; the aggregated delta is applied to the
    server's uncompressed master params), and `fl.transport` the client
    uplink ("int4" adds `fl.group_size`-wide grouped scales).
    `fl.downlink_delta` broadcasts the compressed diff against
    `state.prev_broadcast` instead of the full model.

    When `angle_pred` is None, `fl.angle_filter` selects a built-in
    predicate ("dense_only" -> `moe_dense_only_pred`); an explicit
    `angle_pred` overrides the config.
    """
    if fl.angle_filter not in ("all", "dense_only"):
        raise ValueError(f"unknown angle_filter {fl.angle_filter!r}")
    if angle_pred is None and fl.angle_filter == "dense_only":
        angle_pred = moe_dense_only_pred
    if fl.engine not in ("tree", "flat", "flat_sharded"):
        raise ValueError(f"unknown engine {fl.engine!r}")
    if fl.transport not in transport_mod.TRANSPORTS:
        raise ValueError(
            f"unknown transport {fl.transport!r} (expected one of "
            f"{transport_mod.TRANSPORTS})")
    if fl.downlink not in transport_mod.DOWNLINKS:
        raise ValueError(
            f"unknown downlink {fl.downlink!r} (expected one of "
            f"{transport_mod.DOWNLINKS})")
    if fl.transport == "int4":
        transport_mod.validate_group_size(fl.group_size)
    if fl.error_feedback and fl.transport == "f32":
        raise ValueError(
            "error_feedback carries the quantization residual; transport="
            "'f32' has none (set transport='bf16', 'int8', or 'int4')")
    if fl.downlink_error_feedback and fl.downlink == "f32":
        raise ValueError(
            "downlink_error_feedback carries the broadcast quantization "
            "residual; downlink='f32' has none (set downlink='bf16' or "
            "'int8')")
    if fl.downlink_delta and fl.downlink == "f32":
        raise ValueError(
            "downlink_delta broadcasts the quantized model diff against "
            "the previous broadcast; downlink='f32' ships exact params "
            "and has nothing to gain from it (set downlink='bf16' or "
            "'int8')")
    if fl.engine == "flat_sharded" and mesh is None:
        raise ValueError(
            "engine='flat_sharded' shards the (K, N) delta buffer over "
            "the mesh client axis; pass mesh= to make_round_fn")
    if fl.mode == "parallel":
        return _make_parallel_round(loss_fn, fl, delta_constraint, angle_pred,
                                    grad_constraint, mesh)
    if fl.mode == "sequential":
        if fl.engine != "tree":
            raise ValueError(
                f"engine={fl.engine!r} requires mode='parallel' (sequential "
                "mode never materializes the stacked (K, N) delta buffer; "
                "its stats already stream through round_stats)")
        if fl.transport != "f32":
            raise ValueError(
                "transport compresses the stacked parallel uplink buffer; "
                "sequential mode streams one client at a time (use "
                "mode='parallel' for quantized transport)")
        if fl.downlink != "f32":
            raise ValueError(
                "quantized downlink is threaded through the parallel round "
                "engines; use mode='parallel' for downlink != 'f32'")
        return _make_sequential_round(loss_fn, fl, angle_pred, grad_constraint)
    raise ValueError(fl.mode)


def _lr_at(fl: FLConfig, round_idx):
    return fl.base_lr * fl.lr_decay ** jnp.asarray(round_idx, jnp.float32)


def _resolve_interpret(fl: FLConfig) -> bool:
    if fl.interpret is not None:
        return fl.interpret
    return jax.default_backend() != "tpu"


def _pad_rows(a, kp: int, fill=0.0):
    """Pad axis 0 to kp rows with a constant (client-axis shard padding)."""
    k = a.shape[0]
    if kp == k:
        return a
    pad = jnp.full((kp - k,) + a.shape[1:], fill, a.dtype)
    return jnp.concatenate([a, pad])


def _make_parallel_round(loss_fn, fl: FLConfig, delta_constraint, angle_pred=None,
                         grad_constraint=None, mesh=None):
    round_ops = None
    if fl.engine == "flat_sharded":
        round_ops = fl_shard_map.make_round_ops(
            mesh, alpha=fl.alpha, method=fl.method,
            interpret=_resolve_interpret(fl), transport=fl.transport,
            group_size=fl.group_size)
        row_sharding = fl_shard_map.flat_client_sharding(mesh)
        csize = fl_shard_map.client_axis_size(mesh)

    def round_fn(state: RoundState, batches, sel_idx, data_sizes):
        if fl.error_feedback and state.ef is None:
            raise ValueError(
                "fl.error_feedback=True: state.ef is missing — build the "
                "state with fl.init_round_state (or "
                "transport.init_error_feedback)")
        if fl.downlink_error_feedback and state.dl_ef is None:
            raise ValueError(
                "fl.downlink_error_feedback=True: state.dl_ef is missing "
                "— build the state with fl.init_round_state (or "
                "transport.downlink.init_downlink_error_feedback)")
        if fl.downlink_delta and state.prev_broadcast is None:
            raise ValueError(
                "fl.downlink_delta=True: state.prev_broadcast is missing "
                "— build the state with fl.init_round_state (or "
                "transport.downlink.init_prev_broadcast)")
        params, angle_state = state.params, state.angle
        ef_state, dl_state = state.ef, state.dl_ef
        lr = _lr_at(fl, state.round)

        # ---- server -> client downlink: compress the broadcast model ----
        # The server keeps `params` as its uncompressed master copy (the
        # aggregated delta is applied to it below); every client trains
        # from the SAME dequantized reconstruction, so the three engines
        # cannot fork — the branch is upstream of all of them.
        params_srv = params
        new_dl, new_bcast = dl_state, state.prev_broadcast
        if fl.downlink != "f32":
            pvec, punravel = treemath.tree_ravel(params)
            if fl.downlink_delta:
                # delta encoding: compress the model DIFF against the
                # reconstruction every client already holds — per-round
                # diffs are small, so the quant scales track them tightly.
                pvec = pvec - state.prev_broadcast
            if fl.downlink_error_feedback:
                # EF-SGD mirror: replay the carried broadcast residual,
                # then carry what this round's compression drops.
                pvec = pvec + dl_state
            qd = transport_mod.downlink.compress(pvec, fl.downlink)
            recon = transport_mod.downlink.decompress(qd)
            if fl.downlink_error_feedback:
                new_dl = pvec - recon
            if fl.downlink_delta:
                recon = state.prev_broadcast + recon
                new_bcast = recon
            params = punravel(recon)

        deltas, losses = jax.vmap(
            lambda b: local_update(loss_fn, params, b, lr, fl.prox_mu,
                                   grad_constraint)
        )(batches)
        if delta_constraint is not None:
            deltas = delta_constraint(deltas)

        psi_avg = weighting.fedavg_weights(data_sizes)
        new_ef = ef_state

        # ---- client uplink: compress the stacked deltas to the wire ----
        if fl.transport != "f32":
            flat0, unravel0 = treemath.tree_ravel_stacked(deltas)
            if fl.error_feedback:
                # EF-SGD: replay the carried residual into this round's
                # signal, then carry what quantization drops this round.
                flat0 = flat0 + ef_state[sel_idx]
            q = transport_mod.quantize(flat0, fl.transport,
                                       group_size=fl.group_size)
            if fl.error_feedback:
                new_ef = ef_state.at[sel_idx].set(
                    flat0 - transport_mod.dequantize(q))
            if fl.engine == "tree":
                # reference contract: the tree engine never reads the wire
                # buffer — dequantize back to the stacked tree and run the
                # per-leaf reference reductions on the reconstruction.
                # f32 leaves: rounding the dequantized values to a bf16
                # leaf dtype would add a second loss the flat engines
                # (which stream the wire directly) never incur.
                deltas = treemath.tree_unravel_stacked(
                    deltas, transport_mod.dequantize(q), jnp.float32)

        # (N,) 0/1 segment mask over the ravel order — ONE copy shared by
        # both flat engines (the tree engine masks per-leaf views instead),
        # so the angle_filter semantics cannot fork between them.
        maskv = None
        if fl.engine != "tree" and angle_pred:
            maskv = treemath.segment_mask(params,
                                          angle_keep_list(params, angle_pred))

        if fl.engine == "flat_sharded":
            # the WHOLE round is one shard_map call (stats psums ->
            # replicated Eq.9 + Gompertz weighting -> aggregate psum):
            # rows sharded over ("pod","data"), per-shard fused kernels.
            if fl.transport == "f32":
                flat, unravel = treemath.tree_ravel_stacked(deltas)
                values, scales = flat, None
                n_logical = flat.shape[1]
            else:
                values, scales, unravel = q.values, q.scales, unravel0
                # int4 packs two params per byte: the wire buffer width is
                # NOT the logical width the mask/g vectors live in.
                n_logical = flat0.shape[1]
            k = values.shape[0]
            kp = -(-k // csize) * csize  # pad the client axis to the mesh
            values = jax.lax.with_sharding_constraint(
                _pad_rows(values, kp), row_sharding)
            mvec = (maskv if maskv is not None
                    else jnp.ones((n_logical,), jnp.float32))
            wire = (values,) if scales is None else (
                values, jax.lax.with_sharding_constraint(
                    _pad_rows(scales, kp, 1.0), row_sharding))
            # padded rows: zero deltas, zero data size -> -inf softmax
            # logit -> exactly zero weight and zero stats contribution.
            g_flat, dots, sqs, sqg, delta_flat, theta, _, w = round_ops(
                *wire, _pad_rows(psi_avg, kp), mvec,
                _pad_rows(angle_state.smoothed[sel_idx], kp),
                _pad_rows(angle_state.count[sel_idx], kp),
                _pad_rows(data_sizes, kp))
            dots, sqs = dots[:k], sqs[:k]
            theta, w = theta[:k], w[:k]
            g_avg = unravel(g_flat, jnp.float32)
            delta = unravel(delta_flat)
        elif fl.engine == "flat":
            # single (K, N) ravel; stats + both aggregations are fused
            # single-HBM-pass kernels over the contiguous buffer
            # (chunked over the client axis, so any K fits the VMEM
            # envelope). Quantized wire buffers flow through the
            # fused-dequant kernel variants untouched.
            interpret = _resolve_interpret(fl)
            if fl.transport == "f32":
                flat, unravel = treemath.tree_ravel_stacked(deltas)
                wire_x, wire_s = flat, None
            else:
                unravel = unravel0
                wire_x, wire_s = q.values, q.scales

            def agg_wire(wvec):
                if wire_s is None:
                    return weighted_agg_mod.weighted_agg(
                        wvec, wire_x, interpret=interpret,
                        out_dtype=jnp.float32)
                if fl.transport == "int4":
                    return weighted_agg_mod.weighted_agg_q4(
                        wvec, wire_x, wire_s, n=flat0.shape[1],
                        group_size=fl.group_size, interpret=interpret)
                return weighted_agg_mod.weighted_agg_q(
                    wvec, wire_x, wire_s, interpret=interpret)

            g_flat = agg_wire(psi_avg)
            if wire_s is None:
                dots, sqs, sqg = round_stats_mod.round_stats(
                    wire_x, g_flat, maskv, interpret=interpret)
            elif fl.transport == "int4":
                dots, sqs, sqg = round_stats_mod.round_stats_q4(
                    wire_x, wire_s, g_flat, maskv,
                    group_size=fl.group_size, interpret=interpret)
            else:
                dots, sqs, sqg = round_stats_mod.round_stats_q(
                    wire_x, wire_s, g_flat, maskv, interpret=interpret)
            g_avg = unravel(g_flat, jnp.float32)
            theta = weighting.instantaneous_angle(dots, sqs, sqg)
        else:
            angle_mask = (build_angle_mask(params, angle_pred)
                          if angle_pred else None)
            # f32: rounding g to the (possibly bf16) leaf dtype before
            # the stats would lose the angle signal and diverge from the
            # flat engine; also matches init_prev_delta's f32 threading.
            g_avg = treemath.tree_weighted_sum(deltas, psi_avg,
                                               jnp.float32)
            d_view = angle_mask(deltas) if angle_mask else deltas
            g_view = angle_mask(g_avg) if angle_mask else g_avg
            dots = treemath.tree_vdot_batched(d_view, g_view)
            sqs = treemath.tree_sqnorm_batched(d_view)
            sqg = treemath.tree_sqnorm(g_view)
            theta = weighting.instantaneous_angle(dots, sqs, sqg)

        # Eq. 9 scatter — ONE copy for all engines (flat_sharded computed
        # the same float ops in-region for its weighting; this scatter is
        # its state bookkeeping and must stay op-identical).
        new_state = _scatter_angles(angle_state, sel_idx, theta)
        theta_sm = new_state.smoothed[sel_idx]
        if fl.engine != "flat_sharded":
            if fl.method == "fedadp":
                w = weighting.fedadp_weights(theta_sm, data_sizes, fl.alpha)
            else:  # fedavg / fedprox aggregate by data size
                w = psi_avg
            if fl.engine == "flat":
                # fedavg/fedprox aggregate with w == psi_avg: reuse g_flat
                # rather than re-streaming the buffer (no Pallas CSE)
                delta_flat = g_flat if fl.method != "fedadp" else agg_wire(w)
                delta = unravel(delta_flat)
            else:
                # f32 accumulate, ONE cast to the param leaf dtype — same
                # rounding schedule as the flat engines' unravel, and it
                # keeps params at their dtype when the transport path
                # reconstructed the deltas as f32 leaves.
                delta = jax.tree.map(
                    lambda d, p: d.astype(p.dtype),
                    treemath.tree_weighted_sum(deltas, w, jnp.float32),
                    params)
        # the delta lands on the server's uncompressed master params — the
        # downlink reconstruction is what the CLIENTS trained from.
        new_params = treemath.tree_add(params_srv, delta)

        # Fig.7 divergence: (1/K) sum_i ||dF - dF_i|| with dF ~ -delta/lr
        div = jnp.mean(jnp.sqrt(jnp.maximum(sqs - 2 * dots + sqg, 0.0))) / lr
        metrics = {
            "loss": jnp.mean(losses), "theta": theta, "theta_smoothed": theta_sm,
            "weights": w, "divergence": div, "lr": lr,
            "cos": jnp.cos(theta),
            "expected_contribution": weighting.expected_contribution(w, jnp.cos(theta)),
        }
        return state._replace(
            params=new_params, angle=new_state, prev_delta=g_avg,
            ef=new_ef, dl_ef=new_dl, prev_broadcast=new_bcast,
            round=state.round + 1,
        ), metrics

    return round_fn


def _make_sequential_round(loss_fn, fl: FLConfig, angle_pred=None,
                           grad_constraint=None):
    def round_fn(state: RoundState, batches, sel_idx, data_sizes):
        params, angle_state = state.params, state.angle
        prev_delta = state.prev_delta
        lr = _lr_at(fl, state.round)
        interpret = _resolve_interpret(fl)
        # one stats implementation across modes: pass-2 statistics stream
        # through the round_stats kernel as a single-row (1, N) buffer per
        # scan step, with the MoE angle filter as a flat segment mask.
        maskv = (
            treemath.segment_mask(params, angle_keep_list(params, angle_pred))
            if angle_pred else None
        )
        psi_avg = data_sizes / jnp.sum(data_sizes)
        zeros32 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

        if not fl.stale_angles:
            # ---- pass 1: global (FedAvg-weighted) delta ----
            def p1(acc, xs):
                b_i, psi_i = xs
                d_i, loss = local_update(loss_fn, params, b_i, lr, fl.prox_mu,
                                         grad_constraint)
                return treemath.tree_axpy(psi_i, d_i, acc), loss

            g_avg, losses = jax.lax.scan(p1, zeros32, (batches, psi_avg))
            g_ref = g_avg
        else:
            g_ref = prev_delta
            losses = None

        g_flat, _ = treemath.tree_ravel(g_ref)

        # ---- pass 2 (or single stale pass): stats + online weighted sum ----
        def p2(carry, xs):
            num, den, g_acc = carry
            b_i, psi_i, D_i, idx_i = xs
            d_i, loss = local_update(loss_fn, params, b_i, lr, fl.prox_mu,
                                     grad_constraint)
            d_flat, _ = treemath.tree_ravel(d_i)
            dots_i, sqs_i, sqg_i = round_stats_mod.round_stats(
                d_flat[None], g_flat, maskv, interpret=interpret)
            dot, sq = dots_i[0], sqs_i[0]
            theta_i = weighting.instantaneous_angle(dot, sq, sqg_i)
            cnt = angle_state.count[idx_i].astype(jnp.float32) + 1.0
            sm = ((cnt - 1.0) * angle_state.smoothed[idx_i] + theta_i) / cnt
            if fl.method == "fedadp":
                w_i = D_i * jnp.exp(weighting.gompertz(sm, fl.alpha))
            else:
                w_i = D_i
            num = treemath.tree_axpy(w_i, d_i, num)
            g_acc = treemath.tree_axpy(psi_i, d_i, g_acc)
            return (num, den + w_i, g_acc), (theta_i, sm, dot, sq, sqg_i, loss)

        (num, den, g_acc), ys = jax.lax.scan(
            p2, (zeros32, jnp.zeros((), jnp.float32), zeros32),
            (batches, psi_avg, data_sizes.astype(jnp.float32), sel_idx),
        )
        theta, theta_sm, dots, sqs, sqgs, losses2 = ys
        delta = treemath.tree_scale(num, 1.0 / jnp.maximum(den, 1e-12))
        new_params = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype), params, delta
        )
        new_state = _scatter_angles(angle_state, sel_idx, theta)
        w = (
            weighting.fedadp_weights(theta_sm, data_sizes, fl.alpha)
            if fl.method == "fedadp"
            else psi_avg
        )
        div = jnp.mean(jnp.sqrt(jnp.maximum(sqs - 2 * dots + sqgs, 0.0))) / lr
        metrics = {
            "loss": jnp.mean(losses if losses is not None else losses2),
            "theta": theta, "theta_smoothed": theta_sm, "weights": w,
            "divergence": div, "lr": lr, "cos": jnp.cos(theta),
            "expected_contribution": weighting.expected_contribution(w, jnp.cos(theta)),
        }
        return state._replace(
            params=new_params, angle=new_state, prev_delta=g_acc,
            round=state.round + 1,
        ), metrics

    return round_fn


def init_prev_delta(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
