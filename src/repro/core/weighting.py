"""FedAdp adaptive weighting (paper Eqs. 8-11) and the FedAvg baseline.

All functions are pure and jit-safe; shapes are (K,) vectors over the
participating clients of one round.

Numerical notes:
  * angles are computed in f32 with the cosine clipped to [-1+eps, 1-eps]
    before arccos (gradient of arccos blows up at the boundary, and bf16
    dots can stray slightly outside [-1, 1]).
  * Eq. 11's two cases collapse to a single log-softmax:
      psi_i = D_i e^{f_i} / sum_j D_j e^{f_j} = softmax(f + log D)_i
    (line 1 of Eq. 11 is the equal-D special case).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

DEFAULT_ALPHA = 5.0
_EPS = 1e-7


class AngleState(NamedTuple):
    """Server-side smoothed-angle state (paper Eq. 9), one slot per client.

    `count` is the number of rounds each client has participated in so far
    (the paper's `t` in Eq. 9 — with full participation it is the round
    index; with subset selection it is the per-client participation count).
    """

    smoothed: jax.Array  # (N,) f32, radians
    count: jax.Array  # (N,) i32

    @classmethod
    def init(cls, num_clients: int) -> "AngleState":
        return cls(
            smoothed=jnp.zeros((num_clients,), jnp.float32),
            count=jnp.zeros((num_clients,), jnp.int32),
        )


def cosine_from_stats(dot: jax.Array, sq_a: jax.Array, sq_b: jax.Array) -> jax.Array:
    """cos(theta) from <a,b>, ||a||^2, ||b||^2 — guards zero norms."""
    denom = jnp.sqrt(jnp.maximum(sq_a, _EPS)) * jnp.sqrt(jnp.maximum(sq_b, _EPS))
    return jnp.clip(dot / denom, -1.0 + _EPS, 1.0 - _EPS)


def instantaneous_angle(dot: jax.Array, sq_local: jax.Array, sq_global: jax.Array) -> jax.Array:
    """theta_i(t), Eq. 8 — in radians, elementwise over (K,) stats."""
    return jnp.arccos(cosine_from_stats(dot, sq_local, sq_global))


def update_smoothed_angle(
    state: AngleState, theta: jax.Array, selected: jax.Array
) -> AngleState:
    """Eq. 9 applied to the selected clients' slots.

    selected: (N,) bool mask; theta: (N,) with valid entries where selected.
    """
    new_count = state.count + selected.astype(jnp.int32)
    t = jnp.maximum(new_count, 1).astype(jnp.float32)
    smoothed_upd = ((t - 1.0) * state.smoothed + theta) / t
    smoothed = jnp.where(selected, smoothed_upd, state.smoothed)
    return AngleState(smoothed=smoothed, count=new_count)


def gompertz(theta: jax.Array, alpha: float = DEFAULT_ALPHA) -> jax.Array:
    """Non-linear contribution mapping f(theta), Eq. 10.

    Decreasing in theta; ~alpha for small angles, ~alpha(1-1/e)·small for
    theta -> pi/2 and beyond.
    """
    return alpha * (1.0 - jnp.exp(-jnp.exp(-alpha * (theta - 1.0))))


def fedadp_weights(
    smoothed_theta: jax.Array,
    data_sizes: jax.Array,
    alpha: float = DEFAULT_ALPHA,
) -> jax.Array:
    """Eq. 11 for the K participating clients: softmax(f(theta~) + log D)."""
    f = gompertz(smoothed_theta.astype(jnp.float32), alpha)
    logits = f + jnp.log(data_sizes.astype(jnp.float32))
    return jax.nn.softmax(logits)


def fedavg_weights(data_sizes: jax.Array) -> jax.Array:
    """psi_i = D_i / sum D (Eq. 1)."""
    d = data_sizes.astype(jnp.float32)
    return d / jnp.sum(d)


# ---------------------------------------------------------------- buffered
# Staleness-aware variants for the buffered-async server (FedBuff-style):
# the flush aggregates only the LANDED reports of the in-flight cohort,
# and a report that waited `age` model versions between pulling the
# global params and being applied is discounted by exp(-beta * age) on
# top of its Gompertz contribution weight — late low-contribution nodes
# are doubly suppressed. With every report landed at age 0 the math below
# reduces BIT-EXACTLY to the synchronous Eqs. 1/11 (subtracting
# beta * 0 == 0.0 and multiplying by exp(-0.0) == 1.0 are exact), which
# is what pins buffered(buffer_m=K, no stragglers) == sync.


def staleness_discount(age: jax.Array, beta: float) -> jax.Array:
    """exp(-beta * age): the multiplicative staleness decay of a report
    that waited `age` server model versions before being aggregated."""
    return jnp.exp(-beta * age.astype(jnp.float32))


def buffered_fedadp_weights(
    smoothed_theta: jax.Array,
    data_sizes: jax.Array,
    age: jax.Array,
    landed: jax.Array,
    alpha: float = DEFAULT_ALPHA,
    beta: float = 0.0,
) -> jax.Array:
    """Eq. 11 over the landed reports with the staleness decay folded into
    the softmax logits: softmax(f(theta~) + log D - beta * age), non-landed
    rows at -inf so they get exactly zero weight. Returns zeros when no
    report has landed (the flush is skipped then anyway)."""
    f = gompertz(smoothed_theta.astype(jnp.float32), alpha)
    logits = (f + jnp.log(data_sizes.astype(jnp.float32))
              - beta * age.astype(jnp.float32))
    logits = jnp.where(landed, logits, -jnp.inf)
    w = jax.nn.softmax(logits)
    return jnp.where(jnp.any(landed), w, jnp.zeros_like(w))


def buffered_fedavg_weights(
    data_sizes: jax.Array,
    age: jax.Array,
    landed: jax.Array,
    beta: float = 0.0,
) -> jax.Array:
    """Eq. 1 over the landed reports with the staleness decay applied
    multiplicatively: psi_i = D_i e^{-beta age_i} / sum_landed (same)."""
    s = jnp.where(landed,
                  data_sizes.astype(jnp.float32) * staleness_discount(age, beta),
                  0.0)
    return s / jnp.maximum(jnp.sum(s), 1e-12)


def expected_contribution(weights: jax.Array, cos_theta: jax.Array) -> jax.Array:
    """E_{i|t}[cos theta_i] — the Theorem-1 expectation term.

    Theorem 2 asserts this is >= under FedAdp weights than under FedAvg
    weights; used by the property tests.
    """
    return jnp.sum(weights * cos_theta)
