"""Pytree linear algebra used by the FL aggregation layer.

All reductions are performed in float32 regardless of leaf dtype: angle
computation over bf16 deltas of billions of parameters would otherwise
lose the signal entirely.

The `backend` switch selects between plain-jnp reductions (default,
XLA-fused) and the Pallas kernels in ``repro.kernels`` (TPU-tiled).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _fdot(x: jax.Array, y: jax.Array) -> jax.Array:
    """Shape-preserving f32 dot: sum(x*y) without ravel/reshape.

    Reshaping a sharded leaf to (-1,) merges its model-sharded dim into one
    axis, which GSPMD can only realize with a full all-gather; an
    elementwise multiply + full reduce keeps every leaf sharded and turns
    into shard-local partial sums + one scalar all-reduce.
    """
    return jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))


def tree_dot(a: PyTree, b: PyTree) -> jax.Array:
    """<a, b> over all leaves, accumulated in f32."""
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    assert len(leaves_a) == len(leaves_b)
    return jnp.sum(jnp.stack([_fdot(x, y) for x, y in zip(leaves_a, leaves_b)]))


def tree_sqnorm(a: PyTree) -> jax.Array:
    """||a||^2 over all leaves, accumulated in f32."""
    return jnp.sum(jnp.stack([_fdot(x, x) for x in jax.tree_util.tree_leaves(a)]))


def tree_dot_and_norms(a: PyTree, b: PyTree) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused (<a,b>, ||a||^2, ||b||^2) — one traversal of both trees."""
    dots, na, nb = [], [], []
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        dots.append(_fdot(x, y))
        na.append(_fdot(x, x))
        nb.append(_fdot(y, y))
    return (
        jnp.sum(jnp.stack(dots)),
        jnp.sum(jnp.stack(na)),
        jnp.sum(jnp.stack(nb)),
    )


def tree_scale(a: PyTree, s: jax.Array) -> PyTree:
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * s).astype(x.dtype), a)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_axpy(alpha: jax.Array, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y, computed in f32, cast back to y's dtype."""
    return jax.tree.map(
        lambda xi, yi: (alpha * xi.astype(jnp.float32) + yi.astype(jnp.float32)).astype(yi.dtype),
        x,
        y,
    )


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, a)


def tree_cast(a: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_weighted_sum(trees_stacked: PyTree, weights: jax.Array,
                      dtype=None) -> PyTree:
    """sum_k w[k] * tree[k] for a pytree whose leaves have a leading K axis.

    Used by the client-parallel engine where per-client deltas are stacked
    along axis 0. Accumulates in f32; `dtype` overrides the output leaf
    dtype (default: the input leaf dtype). Pass jnp.float32 when the
    result feeds angle statistics — rounding the global delta to bf16
    first would discard the very signal the f32 reductions preserve.
    """

    def leaf(x):
        w = weights.astype(jnp.float32).reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.sum(x.astype(jnp.float32) * w, axis=0).astype(
            dtype or x.dtype)

    return jax.tree.map(leaf, trees_stacked)


def tree_vdot_batched(stacked: PyTree, single: PyTree) -> jax.Array:
    """[<stacked[k], single> for k] — leaves of `stacked` carry a leading K
    axis. Shape-preserving (see _fdot) so sharded leaves stay sharded."""

    def leaf(x, y):
        axes = tuple(range(1, x.ndim))
        return jnp.sum(
            x.astype(jnp.float32) * y.astype(jnp.float32)[None], axis=axes
        )

    parts = jax.tree_util.tree_leaves(jax.tree.map(leaf, stacked, single))
    return functools.reduce(jnp.add, parts)


def tree_sqnorm_batched(stacked: PyTree) -> jax.Array:
    """[||stacked[k]||^2 for k]."""

    def leaf(x):
        xf = x.astype(jnp.float32)
        return jnp.sum(xf * xf, axis=tuple(range(1, x.ndim)))

    parts = jax.tree_util.tree_leaves(jax.tree.map(leaf, stacked))
    return functools.reduce(jnp.add, parts)


def global_norm(a: PyTree) -> jax.Array:
    return jnp.sqrt(tree_sqnorm(a))


# ---------------------------------------------------------------------------
# Flat-buffer view (the `engine="flat"` round path).
#
# The per-leaf reductions above keep sharded leaves sharded — that is the
# right trade on a mesh. On a single accelerator the opposite holds: one
# contiguous (K, N) buffer lets the whole contribution-measurement +
# aggregation step stream through the fused Pallas kernels in a single HBM
# pass. `tree_ravel_stacked` builds that view once per round; the returned
# unflattener is cached on (treedef, shapes, dtypes) so repeated traces
# reuse the same slice plan.
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _cached_unravel(treedef, shapes, dtypes) -> Callable:
    sizes = [math.prod(s) for s in shapes]
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)

    def unravel(vec: jax.Array, dtype=None) -> PyTree:
        """dtype overrides the recorded leaf dtypes (e.g. jnp.float32 to
        keep an f32 view for angle statistics instead of rounding back)."""
        leaves = [
            jax.lax.slice(vec, (int(offsets[i]),), (int(offsets[i + 1]),))
            .reshape(shapes[i])
            .astype(dtype or dtypes[i])
            for i in range(len(shapes))
        ]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    return unravel


def tree_ravel(tree: PyTree) -> tuple[jax.Array, Callable]:
    """Flatten a pytree into one contiguous (N,) f32 vector.

    Returns (vec, unravel) where unravel(vec) restores the original
    structure, shapes, and leaf dtypes. The unflattener is cached.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    vec = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return vec, _cached_unravel(treedef, shapes, dtypes)


def tree_ravel_stacked(stacked: PyTree,
                       sharding=None) -> tuple[jax.Array, Callable]:
    """Flatten a K-stacked pytree (leaves (K, ...)) into a (K, N) f32 buffer.

    Returns (buf, unravel). unravel maps an (N,) vector back to ONE
    unstacked tree — leaf shapes without the K axis, original dtypes — so
    the aggregated flat delta lands directly in parameter structure.

    `sharding` (a NamedSharding, typically row-sharded over the mesh client
    axis ("pod","data")) pins the buffer's layout via
    with_sharding_constraint — the client-sharded flat engine feeds each
    shard's rows to per-shard kernels, so GSPMD must not all-gather here.
    """
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    k = leaves[0].shape[0]
    shapes = tuple(tuple(l.shape[1:]) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    buf = jnp.concatenate(
        [l.reshape(k, -1).astype(jnp.float32) for l in leaves], axis=1
    )
    if sharding is not None:
        buf = jax.lax.with_sharding_constraint(buf, sharding)
    return buf, _cached_unravel(treedef, shapes, dtypes)


@functools.lru_cache(maxsize=None)
def _cached_unravel_rows(treedef, shapes, dtypes) -> Callable:
    sizes = [math.prod(s) for s in shapes]
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)

    def unravel_rows(buf: jax.Array) -> PyTree:
        k = buf.shape[0]
        leaves = [
            jax.lax.slice(buf, (0, int(offsets[i])), (k, int(offsets[i + 1])))
            .reshape((k,) + shapes[i])
            .astype(dtypes[i])
            for i in range(len(shapes))
        ]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    return unravel_rows


def tree_unravel_stacked(template: PyTree, buf: jax.Array,
                         dtype=None) -> PyTree:
    """Map a (K, N) buffer back to a K-stacked pytree shaped like `template`.

    The inverse of `tree_ravel_stacked`'s forward direction (row k -> client
    k's stacked leaves; `dtype` overrides the leaf dtype, default the
    template's). Used by the transport layer's tree-engine fallback:
    quantize/dequantize the flat buffer, then return to the stacked tree for
    the per-leaf reference reductions — with dtype=f32 there, so a bf16-leaf
    template doesn't put a SECOND lossy rounding on the dequantized values
    that the flat engines (which read the wire directly) never see.
    """
    leaves, treedef = jax.tree_util.tree_flatten(template)
    shapes = tuple(tuple(l.shape[1:]) for l in leaves)
    dtypes = tuple(jnp.dtype(dtype if dtype is not None else l.dtype)
                   for l in leaves)
    return _cached_unravel_rows(treedef, shapes, dtypes)(buf)


# ---------------------------------------------------------------------------
# 2D (client x model) blocked ravel — the flat engine on model-sharded
# meshes. `tree_ravel_stacked` concatenates every leaf's full row, which
# forces GSPMD to all-gather model-sharded leaves; the blocked layout
# instead ravels each MODEL SHARD's local leaf blocks into a per-shard
# column block, inside the shard_map region, so sharded leaves never
# materialize at full width. Every shard's block has the same width (leaf
# segments at the same offsets): a model-sharded leaf contributes its
# exact local size, a replicated leaf is ceil-split into n_shards column
# slices (zero-padded on the last shard). The padding self-masks — padded
# positions are zero in both the rows and the aggregate, so every dot /
# sqnorm contribution is exactly zero. NOTE the blocked element order is a
# (per-shard) permutation of `tree_ravel_stacked`'s order: all the round's
# reductions are permutation-invariant, but quantization chunk/group
# boundaries become SHARD-LOCAL — that is the wire layout contract for 2D
# meshes (scales never straddle a model-axis split).
# ---------------------------------------------------------------------------


class BlockedLayout(NamedTuple):
    """Static description of the per-shard column block (hashable)."""
    n_shards: int
    width: int  # per-shard block width N_loc (sum of per-leaf widths)
    n_logical: int  # global unpadded element count (sum of leaf sizes)
    shapes: tuple  # unstacked global leaf shapes
    dtypes: tuple  # leaf dtypes
    sharded_dims: tuple  # per leaf: model-sharded dim (unstacked) or -1
    widths: tuple  # per leaf: its per-shard segment width


def blocked_layout(stacked: PyTree, pspecs: PyTree, n_shards: int,
                   model_axis: str = "model") -> BlockedLayout:
    """Build the (client x model) block plan for a K-stacked delta tree.

    `stacked`: leaves (K, ...) (arrays or ShapeDtypeStructs); `pspecs`:
    the UNSTACKED param PartitionSpec tree (models/sharding.param_pspecs).
    A leaf whose spec puts `model_axis` on some dim is model-sharded
    (that dim must divide by n_shards — param_pspecs only shards
    divisible dims); every other leaf is replicated over the model axis
    and ceil-split column-wise.
    """
    from jax.sharding import PartitionSpec

    leaves = jax.tree_util.tree_leaves(stacked)
    spec_leaves = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, PartitionSpec))
    assert len(leaves) == len(spec_leaves), "stacked/pspec leaf mismatch"
    shapes, dtypes, sharded_dims, widths = [], [], [], []
    for leaf, spec in zip(leaves, spec_leaves):
        shape = tuple(leaf.shape[1:])
        entries = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
        sdim = -1
        for d, entry in enumerate(entries):
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            if model_axis in names:
                if entry != model_axis:
                    raise ValueError(
                        f"leaf spec {spec} mixes {model_axis!r} with other "
                        "axes on one dim — unsupported by the blocked ravel")
                if sdim >= 0:
                    raise ValueError(
                        f"leaf spec {spec} shards {model_axis!r} twice")
                sdim = d
        size = math.prod(shape) if shape else 1
        if sdim >= 0:
            if shape[sdim] % n_shards:
                raise ValueError(
                    f"model-sharded dim {sdim} of shape {shape} not "
                    f"divisible by {n_shards}")
            w = size // n_shards
        else:
            w = -(-size // n_shards)  # ceil split, zero-padded last shard
        shapes.append(shape)
        dtypes.append(jnp.dtype(leaf.dtype))
        sharded_dims.append(sdim)
        widths.append(w)
    return BlockedLayout(
        n_shards=n_shards, width=sum(widths),
        n_logical=sum(math.prod(s) if s else 1 for s in shapes),
        shapes=tuple(shapes), dtypes=tuple(dtypes),
        sharded_dims=tuple(sharded_dims), widths=tuple(widths))


def blocked_ravel_local(stacked_local_leaves: list, layout: BlockedLayout,
                        shard_index) -> jax.Array:
    """Ravel this model shard's local stacked leaf blocks to (k_loc, width).

    Runs INSIDE a shard_map region: `stacked_local_leaves` are the local
    blocks ((k_loc, *local_shape) for sharded leaves, (k_loc, *shape) for
    replicated ones) and `shard_index` is lax.axis_index(model_axis) — a
    traced scalar selecting each replicated leaf's column slice. Pure
    (no collectives), f32 out.
    """
    m = layout.n_shards
    parts = []
    for x, sdim, w in zip(stacked_local_leaves, layout.sharded_dims,
                          layout.widths):
        k_loc = x.shape[0]
        xf = x.reshape(k_loc, -1).astype(jnp.float32)
        if sdim >= 0:
            parts.append(xf)  # local block IS this shard's segment
        else:
            pad = m * w - xf.shape[1]
            if pad:
                xf = jnp.pad(xf, ((0, 0), (0, pad)))
            parts.append(jax.lax.dynamic_slice_in_dim(
                xf, shard_index * w, w, axis=1))
    return jnp.concatenate(parts, axis=1)


def blocked_split(arr: jax.Array, layout: BlockedLayout) -> list:
    """Split a blocked (..., width) array back into per-leaf segments
    (static offsets; inverse of blocked_ravel_local's concatenation)."""
    out, off = [], 0
    for w in layout.widths:
        out.append(jax.lax.slice_in_dim(arr, off, off + w, axis=-1))
        off += w
    return out


def blocked_segment_mask(layout: BlockedLayout, keep=None) -> jax.Array:
    """(width,) f32 0/1 mask over the blocked order — identical on every
    shard (leaf segments sit at the same offsets in each block). `keep`
    is one bool per leaf (None = all ones); a replicated leaf's zero
    padding is masked out for tidiness (its rows are zero anyway).
    """
    if keep is None:
        keep = [True] * len(layout.widths)
    assert len(keep) == len(layout.widths), "keep/layout leaf mismatch"
    # The mask must be shard-identical, so a replicated leaf's zero-padded
    # tail (last shard only) stays at the leaf's keep value — padded
    # positions are zero in both rows and aggregate, so they contribute
    # exactly zero to every statistic regardless of the mask.
    parts = [np.full(w, 1.0 if k else 0.0, np.float32)
             for w, k in zip(layout.widths, keep)]
    return jnp.asarray(np.concatenate(parts))


def segment_mask(tree: PyTree, keep: list) -> jax.Array:
    """(N,) f32 0/1 mask over the ravel order: 1 where the leaf is kept.

    `keep` is one bool per leaf (same flatten order as `tree_ravel`); the
    mask is a trace-time constant, so masking the flat buffer costs one
    elementwise multiply and no host round-trips.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    assert len(leaves) == len(keep), "keep/tree flatten-order mismatch"
    parts = [
        np.full(math.prod(l.shape), 1.0 if k else 0.0, np.float32)
        for l, k in zip(leaves, keep)
    ]
    return jnp.asarray(np.concatenate(parts))
