"""Pytree linear algebra used by the FL aggregation layer.

All reductions are performed in float32 regardless of leaf dtype: angle
computation over bf16 deltas of billions of parameters would otherwise
lose the signal entirely.

The `backend` switch selects between plain-jnp reductions (default,
XLA-fused) and the Pallas kernels in ``repro.kernels`` (TPU-tiled).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _fdot(x: jax.Array, y: jax.Array) -> jax.Array:
    """Shape-preserving f32 dot: sum(x*y) without ravel/reshape.

    Reshaping a sharded leaf to (-1,) merges its model-sharded dim into one
    axis, which GSPMD can only realize with a full all-gather; an
    elementwise multiply + full reduce keeps every leaf sharded and turns
    into shard-local partial sums + one scalar all-reduce.
    """
    return jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))


def tree_dot(a: PyTree, b: PyTree) -> jax.Array:
    """<a, b> over all leaves, accumulated in f32."""
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    assert len(leaves_a) == len(leaves_b)
    return jnp.sum(jnp.stack([_fdot(x, y) for x, y in zip(leaves_a, leaves_b)]))


def tree_sqnorm(a: PyTree) -> jax.Array:
    """||a||^2 over all leaves, accumulated in f32."""
    return jnp.sum(jnp.stack([_fdot(x, x) for x in jax.tree_util.tree_leaves(a)]))


def tree_dot_and_norms(a: PyTree, b: PyTree) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused (<a,b>, ||a||^2, ||b||^2) — one traversal of both trees."""
    dots, na, nb = [], [], []
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        dots.append(_fdot(x, y))
        na.append(_fdot(x, x))
        nb.append(_fdot(y, y))
    return (
        jnp.sum(jnp.stack(dots)),
        jnp.sum(jnp.stack(na)),
        jnp.sum(jnp.stack(nb)),
    )


def tree_scale(a: PyTree, s: jax.Array) -> PyTree:
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * s).astype(x.dtype), a)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_axpy(alpha: jax.Array, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y, computed in f32, cast back to y's dtype."""
    return jax.tree.map(
        lambda xi, yi: (alpha * xi.astype(jnp.float32) + yi.astype(jnp.float32)).astype(yi.dtype),
        x,
        y,
    )


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, a)


def tree_cast(a: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_weighted_sum(trees_stacked: PyTree, weights: jax.Array,
                      dtype=None) -> PyTree:
    """sum_k w[k] * tree[k] for a pytree whose leaves have a leading K axis.

    Used by the client-parallel engine where per-client deltas are stacked
    along axis 0. Accumulates in f32; `dtype` overrides the output leaf
    dtype (default: the input leaf dtype). Pass jnp.float32 when the
    result feeds angle statistics — rounding the global delta to bf16
    first would discard the very signal the f32 reductions preserve.
    """

    def leaf(x):
        w = weights.astype(jnp.float32).reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.sum(x.astype(jnp.float32) * w, axis=0).astype(
            dtype or x.dtype)

    return jax.tree.map(leaf, trees_stacked)


def tree_vdot_batched(stacked: PyTree, single: PyTree) -> jax.Array:
    """[<stacked[k], single> for k] — leaves of `stacked` carry a leading K
    axis. Shape-preserving (see _fdot) so sharded leaves stay sharded."""

    def leaf(x, y):
        axes = tuple(range(1, x.ndim))
        return jnp.sum(
            x.astype(jnp.float32) * y.astype(jnp.float32)[None], axis=axes
        )

    parts = jax.tree_util.tree_leaves(jax.tree.map(leaf, stacked, single))
    return functools.reduce(jnp.add, parts)


def tree_sqnorm_batched(stacked: PyTree) -> jax.Array:
    """[||stacked[k]||^2 for k]."""

    def leaf(x):
        xf = x.astype(jnp.float32)
        return jnp.sum(xf * xf, axis=tuple(range(1, x.ndim)))

    parts = jax.tree_util.tree_leaves(jax.tree.map(leaf, stacked))
    return functools.reduce(jnp.add, parts)


def global_norm(a: PyTree) -> jax.Array:
    return jnp.sqrt(tree_sqnorm(a))


# ---------------------------------------------------------------------------
# Flat-buffer view (the `engine="flat"` round path).
#
# The per-leaf reductions above keep sharded leaves sharded — that is the
# right trade on a mesh. On a single accelerator the opposite holds: one
# contiguous (K, N) buffer lets the whole contribution-measurement +
# aggregation step stream through the fused Pallas kernels in a single HBM
# pass. `tree_ravel_stacked` builds that view once per round; the returned
# unflattener is cached on (treedef, shapes, dtypes) so repeated traces
# reuse the same slice plan.
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _cached_unravel(treedef, shapes, dtypes) -> Callable:
    sizes = [math.prod(s) for s in shapes]
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)

    def unravel(vec: jax.Array, dtype=None) -> PyTree:
        """dtype overrides the recorded leaf dtypes (e.g. jnp.float32 to
        keep an f32 view for angle statistics instead of rounding back)."""
        leaves = [
            jax.lax.slice(vec, (int(offsets[i]),), (int(offsets[i + 1]),))
            .reshape(shapes[i])
            .astype(dtype or dtypes[i])
            for i in range(len(shapes))
        ]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    return unravel


def tree_ravel(tree: PyTree) -> tuple[jax.Array, Callable]:
    """Flatten a pytree into one contiguous (N,) f32 vector.

    Returns (vec, unravel) where unravel(vec) restores the original
    structure, shapes, and leaf dtypes. The unflattener is cached.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    vec = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return vec, _cached_unravel(treedef, shapes, dtypes)


def tree_ravel_stacked(stacked: PyTree,
                       sharding=None) -> tuple[jax.Array, Callable]:
    """Flatten a K-stacked pytree (leaves (K, ...)) into a (K, N) f32 buffer.

    Returns (buf, unravel). unravel maps an (N,) vector back to ONE
    unstacked tree — leaf shapes without the K axis, original dtypes — so
    the aggregated flat delta lands directly in parameter structure.

    `sharding` (a NamedSharding, typically row-sharded over the mesh client
    axis ("pod","data")) pins the buffer's layout via
    with_sharding_constraint — the client-sharded flat engine feeds each
    shard's rows to per-shard kernels, so GSPMD must not all-gather here.
    """
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    k = leaves[0].shape[0]
    shapes = tuple(tuple(l.shape[1:]) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    buf = jnp.concatenate(
        [l.reshape(k, -1).astype(jnp.float32) for l in leaves], axis=1
    )
    if sharding is not None:
        buf = jax.lax.with_sharding_constraint(buf, sharding)
    return buf, _cached_unravel(treedef, shapes, dtypes)


@functools.lru_cache(maxsize=None)
def _cached_unravel_rows(treedef, shapes, dtypes) -> Callable:
    sizes = [math.prod(s) for s in shapes]
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)

    def unravel_rows(buf: jax.Array) -> PyTree:
        k = buf.shape[0]
        leaves = [
            jax.lax.slice(buf, (0, int(offsets[i])), (k, int(offsets[i + 1])))
            .reshape((k,) + shapes[i])
            .astype(dtypes[i])
            for i in range(len(shapes))
        ]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    return unravel_rows


def tree_unravel_stacked(template: PyTree, buf: jax.Array,
                         dtype=None) -> PyTree:
    """Map a (K, N) buffer back to a K-stacked pytree shaped like `template`.

    The inverse of `tree_ravel_stacked`'s forward direction (row k -> client
    k's stacked leaves; `dtype` overrides the leaf dtype, default the
    template's). Used by the transport layer's tree-engine fallback:
    quantize/dequantize the flat buffer, then return to the stacked tree for
    the per-leaf reference reductions — with dtype=f32 there, so a bf16-leaf
    template doesn't put a SECOND lossy rounding on the dequantized values
    that the flat engines (which read the wire directly) never see.
    """
    leaves, treedef = jax.tree_util.tree_flatten(template)
    shapes = tuple(tuple(l.shape[1:]) for l in leaves)
    dtypes = tuple(jnp.dtype(dtype if dtype is not None else l.dtype)
                   for l in leaves)
    return _cached_unravel_rows(treedef, shapes, dtypes)(buf)


def segment_mask(tree: PyTree, keep: list) -> jax.Array:
    """(N,) f32 0/1 mask over the ravel order: 1 where the leaf is kept.

    `keep` is one bool per leaf (same flatten order as `tree_ravel`); the
    mask is a trace-time constant, so masking the flat buffer costs one
    elementwise multiply and no host round-trips.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    assert len(leaves) == len(keep), "keep/tree flatten-order mismatch"
    parts = [
        np.full(math.prod(l.shape), 1.0 if k else 0.0, np.float32)
        for l, k in zip(leaves, keep)
    ]
    return jnp.asarray(np.concatenate(parts))
