"""Pytree linear algebra used by the FL aggregation layer.

All reductions are performed in float32 regardless of leaf dtype: angle
computation over bf16 deltas of billions of parameters would otherwise
lose the signal entirely.

The `backend` switch selects between plain-jnp reductions (default,
XLA-fused) and the Pallas kernels in ``repro.kernels`` (TPU-tiled).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def _fdot(x: jax.Array, y: jax.Array) -> jax.Array:
    """Shape-preserving f32 dot: sum(x*y) without ravel/reshape.

    Reshaping a sharded leaf to (-1,) merges its model-sharded dim into one
    axis, which GSPMD can only realize with a full all-gather; an
    elementwise multiply + full reduce keeps every leaf sharded and turns
    into shard-local partial sums + one scalar all-reduce.
    """
    return jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))


def tree_dot(a: PyTree, b: PyTree) -> jax.Array:
    """<a, b> over all leaves, accumulated in f32."""
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    assert len(leaves_a) == len(leaves_b)
    return jnp.sum(jnp.stack([_fdot(x, y) for x, y in zip(leaves_a, leaves_b)]))


def tree_sqnorm(a: PyTree) -> jax.Array:
    """||a||^2 over all leaves, accumulated in f32."""
    return jnp.sum(jnp.stack([_fdot(x, x) for x in jax.tree_util.tree_leaves(a)]))


def tree_dot_and_norms(a: PyTree, b: PyTree) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused (<a,b>, ||a||^2, ||b||^2) — one traversal of both trees."""
    dots, na, nb = [], [], []
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        dots.append(_fdot(x, y))
        na.append(_fdot(x, x))
        nb.append(_fdot(y, y))
    return (
        jnp.sum(jnp.stack(dots)),
        jnp.sum(jnp.stack(na)),
        jnp.sum(jnp.stack(nb)),
    )


def tree_scale(a: PyTree, s: jax.Array) -> PyTree:
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * s).astype(x.dtype), a)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_axpy(alpha: jax.Array, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y, computed in f32, cast back to y's dtype."""
    return jax.tree.map(
        lambda xi, yi: (alpha * xi.astype(jnp.float32) + yi.astype(jnp.float32)).astype(yi.dtype),
        x,
        y,
    )


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, a)


def tree_cast(a: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_weighted_sum(trees_stacked: PyTree, weights: jax.Array) -> PyTree:
    """sum_k w[k] * tree[k] for a pytree whose leaves have a leading K axis.

    Used by the client-parallel engine where per-client deltas are stacked
    along axis 0. Accumulates in f32.
    """

    def leaf(x):
        w = weights.astype(jnp.float32).reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.sum(x.astype(jnp.float32) * w, axis=0).astype(x.dtype)

    return jax.tree.map(leaf, trees_stacked)


def tree_vdot_batched(stacked: PyTree, single: PyTree) -> jax.Array:
    """[<stacked[k], single> for k] — leaves of `stacked` carry a leading K
    axis. Shape-preserving (see _fdot) so sharded leaves stay sharded."""

    def leaf(x, y):
        axes = tuple(range(1, x.ndim))
        return jnp.sum(
            x.astype(jnp.float32) * y.astype(jnp.float32)[None], axis=axes
        )

    parts = jax.tree_util.tree_leaves(jax.tree.map(leaf, stacked, single))
    return functools.reduce(jnp.add, parts)


def tree_sqnorm_batched(stacked: PyTree) -> jax.Array:
    """[||stacked[k]||^2 for k]."""

    def leaf(x):
        xf = x.astype(jnp.float32)
        return jnp.sum(xf * xf, axis=tuple(range(1, x.ndim)))

    parts = jax.tree_util.tree_leaves(jax.tree.map(leaf, stacked))
    return functools.reduce(jnp.add, parts)


def global_norm(a: PyTree) -> jax.Array:
    return jnp.sqrt(tree_sqnorm(a))
