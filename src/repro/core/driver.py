"""Device-resident federated training driver.

`core.server.FedServer` used to pay a full host round-trip per round:
numpy epoch batching, one jit dispatch, a `device_get`, and a host-side
eval — so on small models the wall clock was dominated by dispatch/sync
overhead rather than the round kernels. This module moves the whole loop
onto the device:

* **Data pipeline** — the node datasets are stacked ONCE into device
  arrays (`stack_nodes`); per-round, per-client epoch permutations are
  drawn with `jax.random` inside the compiled step (`epoch_batches`), so
  no host batching or H2D copy happens between rounds. Ragged node sizes
  are handled by a masked-argsort permutation (padding rows are never
  sampled); `batch_size > min node size` (tau = 0 local steps) raises a
  clear ValueError naming the offending node instead of a reshape error.

* **Round step** — `make_step_fn` folds client selection (device RNG,
  subset without replacement), batching, the `fl.make_round_fn` round,
  and an optional in-scan eval into one `step(state, eval_every)` whose
  carry is the unified `fl.RoundState`. The same step drives BOTH the
  stepwise server (one jit dispatch per round — the per-round tests'
  path) and the scanned driver, which is what pins scanned == stepwise.

* **Scanned driver** — `make_scan_runner` wraps the step in a
  `lax.scan` over a block of E rounds (jit-compiled once per block
  length, state buffers donated so params/EF update in place off-CPU);
  `run_rounds` chains blocks with a host-side early-exit check between
  them, preserving the paper's Table-I semantics exactly: an eval fires
  after rounds where (r+1) % eval_every == 0, and rounds_to_target is
  the first such round whose accuracy reaches the target (the scan may
  run up to one block past it; the report is exact). `run_rounds` can
  also snapshot the full RoundState at block boundaries
  (`ckpt_dir=` / `ckpt_every_blocks=`) so a preempted run restores
  bit-exactly via `fl.state_from_tree` + `checkpoint.io.load_latest`.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.core import buffer as buffer_mod
from repro.core import fl as fl_mod
from repro.telemetry import schema as tel_schema
from repro.telemetry import sinks as tel_sinks
from repro.telemetry import spans as tel_spans

PyTree = Any

# the in-scan eval fill value for rounds where the lax.cond-gated eval
# did not run — owned by the telemetry schema so sinks/flstat mask the
# SAME constant the compiled step writes (never ingest it as data).
EVAL_SENTINEL = tel_schema.EVAL_SENTINEL


class ClientData(NamedTuple):
    """Device-resident stacked node datasets.

    x/y are stacked over the client axis and zero-padded to the largest
    node (`sizes` keeps the true per-node counts; the epoch permutation
    never samples a padded row). `tau` = n_i // batch_size is the static
    per-round local step count — equal across nodes by construction
    (stacked (K, tau, B, ...) round batches admit exactly one tau).
    """

    x: jax.Array  # (C, n_max, ...) features
    y: jax.Array  # (C, n_max) int labels
    sizes: jax.Array  # (C,) i32 true per-node sample counts
    tau: int  # local steps per round (static)
    batch_size: int  # B (static)


def stack_nodes(nodes: list, batch_size: int) -> ClientData:
    """Stack host node datasets into one device-resident ClientData.

    Raises ValueError when a node is too small for even one batch
    (tau = len // batch_size = 0 — the old numpy batcher crashed with an
    opaque reshape error here) or when nodes disagree on tau.
    """
    taus = [len(ds.y) // batch_size for ds in nodes]
    for i, (ds, tau) in enumerate(zip(nodes, taus)):
        if tau < 1:
            raise ValueError(
                f"node {i} has {len(ds.y)} samples but batch_size="
                f"{batch_size}: tau = {len(ds.y)}//{batch_size} = 0 local "
                "steps — lower batch_size or grow the node's dataset")
    if len(set(taus)) != 1:
        raise ValueError(
            f"nodes disagree on local steps tau = n_i//batch_size: {taus} "
            "— stacked (K, tau, B, ...) round batches admit exactly one "
            "tau (equalize node sizes or batch them separately)")
    n_max = max(len(ds.y) for ds in nodes)

    def pad(a):
        if a.shape[0] == n_max:
            return a
        fill = np.zeros((n_max - a.shape[0],) + a.shape[1:], a.dtype)
        return np.concatenate([a, fill])

    return ClientData(
        x=jnp.asarray(np.stack([pad(np.asarray(ds.x)) for ds in nodes])),
        y=jnp.asarray(np.stack([pad(np.asarray(ds.y)) for ds in nodes])),
        sizes=jnp.asarray([len(ds.y) for ds in nodes], jnp.int32),
        tau=taus[0],
        batch_size=batch_size,
    )


def select_clients(key, num_clients: int, k: int) -> jax.Array:
    """(k,) i32 population slots for this round's cohort.

    Full participation (k >= num_clients) is the deterministic identity —
    matching the host server's old behaviour bit-for-bit; a strict subset
    is drawn uniformly without replacement from the device RNG.
    """
    if k >= num_clients:
        return jnp.arange(num_clients, dtype=jnp.int32)
    return jax.random.permutation(key, num_clients)[:k].astype(jnp.int32)


def select_clients_avoiding(key, num_clients: int, k: int,
                            busy: jax.Array) -> jax.Array:
    """Subset selection that prefers clients with no report in flight.

    The buffered server must not re-select a busy client (its new report
    would collide with the buffered one in the Eq. 9 scatter), so busy
    clients sort strictly after every free one: uniform keys in [0, 1)
    get +1 where busy, and the k smallest win. Only when fewer than k
    clients are free do busy ones appear among the candidates — and the
    round's admission mask (`core.buffer`) filters those out. Full
    participation stays the deterministic identity (`select_clients`):
    every client is a candidate every tick; admission masks the busy ones.
    """
    if k >= num_clients:
        return jnp.arange(num_clients, dtype=jnp.int32)
    u = jax.random.uniform(key, (num_clients,))
    u = jnp.where(busy, u + 1.0, u)
    return jnp.argsort(u)[:k].astype(jnp.int32)


def epoch_batches(key, data: ClientData, sel: jax.Array):
    """One epoch of shuffled minibatches per selected client, on device.

    Returns (xb, yb) with leaves (K, tau, B, ...) — the paper's
    tau = E*D_i/B with E=1, exactly what the numpy `_epoch_batcher`
    yielded, but drawn from the device RNG: per-client keys are folded
    from the GLOBAL population slot, so a client's stream depends only on
    (round key, client id), never on who else was selected. Ragged node
    sizes use a masked argsort (rows past sizes[c] get +inf and sort
    last), so padding is never sampled.
    """
    count = data.tau * data.batch_size
    n_max = data.x.shape[1]

    def one(c):
        k = jax.random.fold_in(key, c)
        u = jax.random.uniform(k, (n_max,))
        u = jnp.where(jnp.arange(n_max) < data.sizes[c], u, jnp.inf)
        idx = jnp.argsort(u)[:count]
        xb = data.x[c][idx].reshape(
            (data.tau, data.batch_size) + data.x.shape[2:])
        yb = data.y[c][idx].reshape(data.tau, data.batch_size)
        return xb, yb

    return jax.vmap(one)(sel)


def make_eval_fn(apply_fn: Callable, test_x, test_y,
                 chunk: int = 2048) -> Callable:
    """Device-side test accuracy: params -> f32 fraction correct.

    The test set is padded to a multiple of `chunk` with label -1 (argmax
    over real logits is never negative, so padding can't score) and
    scanned in chunks, bounding eval activation memory for conv models.
    """
    n = test_x.shape[0]
    chunk = min(chunk, n)
    m = -(-n // chunk)
    pad = m * chunk - n
    xs = jnp.concatenate(
        [jnp.asarray(test_x),
         jnp.zeros((pad,) + test_x.shape[1:], test_x.dtype)])
    ys = jnp.concatenate(
        [jnp.asarray(test_y, jnp.int32), jnp.full((pad,), -1, jnp.int32)])
    xs = xs.reshape((m, chunk) + test_x.shape[1:])
    ys = ys.reshape(m, chunk)

    def eval_fn(params):
        def body(tot, xy):
            xc, yc = xy
            pred = jnp.argmax(apply_fn(params, xc), axis=-1)
            return tot + jnp.sum((pred == yc).astype(jnp.int32)), None

        correct, _ = jax.lax.scan(body, jnp.int32(0), (xs, ys))
        return correct.astype(jnp.float32) / n

    return eval_fn


def make_step_fn(loss_fn: Callable, fl: fl_mod.FLConfig, data: ClientData,
                 *, eval_fn: Optional[Callable] = None,
                 angle_pred: Optional[Callable] = None,
                 mesh=None, arrival_fn: Optional[Callable] = None) -> Callable:
    """One fully device-resident federated round.

    step(state, eval_every) -> (state, metrics): split the state's RNG,
    select this round's cohort, draw each client's epoch batches, run the
    compiled round, and (when `eval_fn` is given) conditionally append
    `metrics["accuracy"]` — evaluated only after rounds where
    round % eval_every == 0 post-increment (i.e. (r+1) % eval_every == 0),
    the named `EVAL_SENTINEL` (-1.0) otherwise, so the eval forward pass
    is skipped via `lax.cond` on non-eval rounds. `eval_every` is a
    traced i32 (0 disables eval without recompiling). Sinks and
    `scripts/flstat.py` mask the sentinel; host code must test
    `acc != EVAL_SENTINEL` rather than reinvent the fill value.

    The SAME function is the stepwise server's jitted step and the
    scanned driver's scan body — equivalence by construction.

    With `fl.aggregation == "buffered"` each step is one server TICK
    (see `fl._make_buffered_round`): subset selection avoids clients
    whose report is still in flight (`select_clients_avoiding` over
    `state.buf`), and `arrival_fn` (an explicit per-tick delay/dropout
    schedule, e.g. `core.server.fixed_arrival_schedule`) flows through
    to the round builder. Both are inert for sync configs.
    """
    buffered = fl.aggregation == "buffered"
    round_fn = fl_mod.make_round_fn(loss_fn, fl, angle_pred=angle_pred,
                                    mesh=mesh, arrival_fn=arrival_fn)

    def step(state: fl_mod.RoundState, eval_every):
        rng, k_sel, k_bat = jax.random.split(state.rng, 3)
        if buffered and fl.clients_per_round < fl.num_clients:
            busy = buffer_mod.population_busy(state.buf, fl.num_clients)
            sel = select_clients_avoiding(k_sel, fl.num_clients,
                                          fl.clients_per_round, busy)
        else:
            sel = select_clients(k_sel, fl.num_clients, fl.clients_per_round)
        batches = epoch_batches(k_bat, data, sel)
        sizes = data.sizes[sel].astype(jnp.float32)
        state, metrics = round_fn(state._replace(rng=rng), batches, sel,
                                  sizes)
        if eval_fn is not None:
            do_eval = (eval_every > 0) & (state.round % eval_every == 0)
            acc = jax.lax.cond(do_eval, eval_fn,
                               lambda p: jnp.float32(EVAL_SENTINEL),
                               state.params)
            metrics = dict(metrics, accuracy=acc)
        return state, metrics

    return step


def make_scan_runner(step_fn: Callable, donate: Optional[bool] = None):
    """jit-compiled `lax.scan` of `step_fn` over a static block length.

    run_block(state, eval_every, length=E) -> (state, stacked metrics).
    The RoundState carry is donated (params/EF buffers update in place)
    on backends that implement donation; CPU XLA does not, so donation
    defaults off there to avoid per-call warnings.
    """
    if donate is None:
        donate = jax.default_backend() != "cpu"

    def run_block(state, eval_every, length):
        def body(s, _):
            return step_fn(s, eval_every)

        return jax.lax.scan(body, state, length=length)

    kw = {"static_argnames": ("length",)}
    if donate:
        kw["donate_argnums"] = (0,)
    return jax.jit(run_block, **kw)


def run_rounds(run_block: Callable, state: fl_mod.RoundState, rounds: int,
               *, eval_every: int = 1, target_acc: Optional[float] = None,
               block: int = 8, ckpt_dir: Optional[str] = None,
               ckpt_every_blocks: int = 1, ckpt_keep: int = 3,
               sink=None, telemetry_every: int = 1,
               spans: Optional[tel_spans.SpanTimer] = None):
    """Chunked scan over rounds with host-side early exit and optional
    block-boundary checkpointing.

    Scans `block` rounds per dispatch (one compile per distinct block
    length — at most two: the block and the final remainder); between
    blocks the host checks the in-scan eval accuracies against
    `target_acc`. Table-I semantics are preserved: rounds_to_target is
    the exact (r+1) of the first eval round at or above the target, even
    though the device may have run to the end of that block. Rounds are
    counted GLOBALLY from `state.round` — a state restored from a
    checkpoint at round R resumes at R, its eval cadence stays phased on
    the absolute round index, and rounds_to_target reports the same
    number the uninterrupted run would.

    `ckpt_dir` snapshots the FULL RoundState (fl.state_to_tree ->
    checkpoint.io.save_checkpoint: atomic write + `latest` pointer,
    newest `ckpt_keep` archives retained) after every
    `ckpt_every_blocks`-th block and always at exit, so a killed run
    loses at most `ckpt_every_blocks * block` rounds and restores
    bit-exactly (fl.state_from_tree) at a block boundary.

    `sink` (a `telemetry.sinks.TelemetrySink`) receives schema events at
    every scan-block boundary — one ``round`` event per round run (the
    final partial block is exact-length, never padded, so no de-padding
    ambiguity reaches the stream) plus per-node rows when the config's
    `telemetry="node"` metrics are present; `telemetry_every` subsamples
    the emitted rounds. `spans` (a `telemetry.spans.SpanTimer`; one is
    created over `sink` when omitted) bounds each block dispatch +
    device_get as a ``scan_block`` span, checkpoint writes as
    ``checkpoint``, and event emission as ``sink_emit`` — the
    wall-clock-per-round numbers flstat reports come from these.

    Returns (state, metrics, rounds_to_target, rounds_run) where metrics
    holds per-round host arrays stacked over every round run THIS call
    (`rounds_run` counts the same; rounds_to_target is absolute).
    """
    base = int(jax.device_get(state.round))
    saved_at = None
    if spans is None:
        spans = tel_spans.SpanTimer(sink)

    def checkpoint(round_now):
        nonlocal saved_at
        with spans.span("checkpoint", round=round_now):
            ckpt_io.save_checkpoint(ckpt_dir, round_now,
                                    fl_mod.state_to_tree(state),
                                    keep=ckpt_keep)
        saved_at = round_now

    blocks = []
    done = 0
    n_blocks = 0
    rounds_to_target = None
    while done < rounds and rounds_to_target is None:
        length = min(block, rounds - done)
        with spans.span("scan_block", round=base + done):
            state, ms = run_block(state, jnp.int32(eval_every),
                                  length=length)
            ms = jax.device_get(ms)
        blocks.append(ms)
        if sink is not None:
            with spans.span("sink_emit", round=base + done):
                tel_sinks.emit_round_block(sink, ms, base + done,
                                           every=telemetry_every)
        if target_acc is not None and "accuracy" in ms:
            hit = np.flatnonzero(np.asarray(ms["accuracy"]) >= target_acc)
            if hit.size:
                rounds_to_target = base + done + int(hit[0]) + 1
        done += length
        n_blocks += 1
        if ckpt_dir is not None and n_blocks % ckpt_every_blocks == 0:
            checkpoint(base + done)
    if ckpt_dir is not None and saved_at != base + done:
        checkpoint(base + done)
    metrics = {
        k: np.concatenate([np.atleast_1d(np.asarray(m[k])) for m in blocks])
        for k in blocks[0]
    } if blocks else {}
    return state, metrics, rounds_to_target, done
