"""FedAdp aggregation as an explicit shard_map collective schedule.

The pjit engine (core/fl.py) leaves collective placement to GSPMD. This
module expresses the SAME aggregation — the paper's actual contribution —
with hand-placed collectives under `jax.shard_map`, which makes the
communication pattern auditable and lets §Perf reason about it directly:

  per model-shard:   g_avg = psum_{clients}(psi_i * delta_i)        (1)
  per client:        dot_i = psum_{model}(<delta_i, g_avg>_shard)   (2)
                     |d_i|^2, |g|^2 likewise
  replicated:        theta -> Gompertz -> softmax weights           (3)
  per model-shard:   delta = psum_{clients}(w_i * delta_i)          (4)

Exactly two client-axis tree reductions (1)(4) plus O(K) scalar psums (2)
per round — the minimum the algorithm admits with exact same-round angles.

Two engines share this schedule:

* ``engine="tree"`` (reference) — per-leaf reductions; tensor dims may be
  sharded over the model axes, so big-model leaves stay sharded.
* ``engine="flat"`` — the stacked deltas are raveled once into a (K, N)
  f32 buffer row-sharded over the client axis ("pod","data"); steps
  (1)(2)(4) run as the fused Pallas kernels (`kernels.weighted_agg`,
  `kernels.round_stats`) on each shard's rows, followed by the same psums.
  This is the scalable large-cohort path: per-device work is one HBM pass
  over K/num_shards rows regardless of K. It requires client-only
  sharding (each client's delta row is contiguous on its shard).

`make_round_ops` packages the whole flat round — stats psums, the
replicated O(K) weighting, and the aggregate psum — as ONE shard_map
region; core/fl.py's `engine="flat_sharded"` round path reuses it so the
pjit and shard_map stacks aggregate through literally the same kernels.
The RoundState contract lives one level up: core/fl.py gathers the
selected clients' Eq. 9 slots out of `RoundState.angle` before entering
this region and scatters the results back after it, so the shard_map
schedule stays a pure (K,)-shaped aggregation op and the region composes
unchanged with the scanned driver (`core.driver` puts the whole round —
this region included — inside `lax.scan`).

Works on any mesh whose client axis is "data" (+"pod") and whose tensor
axes follow models/sharding.param_pspecs; on a 1x1 host mesh it reduces to
plain math (used by the CPU equivalence test).

Telemetry contract: the per-client quantities this schedule produces
(theta, smoothed theta, softmax weights) leave the shard_map region
replicated, so the `FLConfig(telemetry="node")` tel/* metrics built from
them in core/fl.py are exact per-node rows — identical across shards and
matching the unsharded engines to 1e-5 (pinned by tests/test_telemetry.py's
8-device subprocess leg).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import treemath, weighting
from repro.kernels import round_stats as round_stats_mod
from repro.kernels import weighted_agg as weighted_agg_mod

PyTree = Any


def _client_axes(mesh: Mesh):
    caxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not caxes:
        raise ValueError(
            f"mesh axes {mesh.axis_names} contain no client axis — the "
            "FedAdp client dimension shards over ('pod', 'data')")
    return caxes


def client_axis_size(mesh: Mesh) -> int:
    size = 1
    for a in _client_axes(mesh):
        size *= mesh.shape[a]
    return size


def flat_client_sharding(mesh: Mesh) -> NamedSharding:
    """Row sharding for the (K, N) flat delta buffer: K over ("pod","data")."""
    caxes = _client_axes(mesh)
    return NamedSharding(mesh, P(caxes if len(caxes) > 1 else caxes[0]))


def _shard_map(body, mesh: Mesh, in_specs, out_specs):
    """shard_map across jax versions (check_vma / check_rep spelling)."""
    try:
        smap = jax.shard_map
    except AttributeError:  # older jax
        from jax.experimental.shard_map import shard_map as smap
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    try:
        return smap(body, check_vma=False, **kw)
    except TypeError:  # jax < 0.6 spells it check_rep
        return smap(body, check_rep=False, **kw)


def _client_axis(mesh: Mesh):
    caxes = _client_axes(mesh)
    return caxes if len(caxes) > 1 else caxes[0]


def _shard_slots(values, caxis):
    """Global client slots owned by this shard (rows are client-sharded)."""
    k_loc = values.shape[0]
    return jax.lax.axis_index(caxis) * k_loc + jnp.arange(k_loc)


def _shard_agg(w_loc, values, scales, interpret, *, transport, n,
               group_size):
    """Per-shard weighted aggregation over the local rows, f32 out.

    scales is None for f32/bf16 wire buffers (the kernels' in-VMEM
    astype(f32) IS the bf16 dequant); int8 routes through the fused
    in-register dequant kernel with the per-(client, chunk) scales, int4
    through the grouped-scale packed-nibble kernel (`n` is the logical
    width the packed buffer unpacks to).
    """
    if scales is None:
        return weighted_agg_mod.weighted_agg(
            w_loc, values, interpret=interpret, out_dtype=jnp.float32)
    if transport == "int4":
        return weighted_agg_mod.weighted_agg_q4(
            w_loc, values, scales, n=n, group_size=group_size,
            interpret=interpret)
    return weighted_agg_mod.weighted_agg_q(
        w_loc, values, scales, interpret=interpret)


def _shard_stats(values, scales, g_flat, mask, interpret, *, transport,
                 group_size):
    """Per-shard fused angle statistics over the local rows."""
    if scales is None:
        return round_stats_mod.round_stats(
            values, g_flat, mask, interpret=interpret)
    if transport == "int4":
        return round_stats_mod.round_stats_q4(
            values, scales, g_flat, mask, group_size=group_size,
            interpret=interpret)
    return round_stats_mod.round_stats_q(
        values, scales, g_flat, mask, interpret=interpret)


def make_round_ops(mesh: Mesh, *, alpha: float, method: str = "fedadp",
                   interpret: bool = True, transport: str = "f32",
                   group_size: int = 0):
    """The whole aggregation round as ONE shard_map call.

    PR 2's `make_flat_ops` exposed stats and aggregate as two separate
    shard_map regions, which re-entered the collective schedule (and
    re-staged the row shards) between them. The weighting in between is
    O(K) replicated scalar math — Eq. 9 smoothing + Gompertz softmax — so
    it folds into the same region: stats psums -> replicated weighting ->
    aggregate psum, one schedule, the buffer staying put on its shard
    (the two-region form is gone; this is the only flat schedule). For
    fedavg/fedprox the weighting IS psi, so the aggregate reuses the
    stats' g_flat and the round is a single client-axis reduction.

    transport selects the buffer's wire dtype (repro.transport):
    "f32"/"bf16" stream it through the plain kernels (bf16 dequant is the
    kernels' in-VMEM astype); "int8" adds a row-sharded
    (K, num_chunks(N)) f32 scales operand and routes through the fused
    in-register dequant kernels; "int4" row-shards the PACKED
    (K, ceil(N/2)) byte buffer plus its (K, num_groups) grouped scales
    (`group_size` required) through the packed-nibble kernels — in every
    case the per-shard partial dots/sqnorms and aggregates are psum'd
    exactly as in the f32 path, so scales never cross shards. mask is a
    REQUIRED (N,) f32 vector in LOGICAL element space (pass ones for
    unfiltered stats — multiplying by 1.0 is exact in f32, so the result
    is bit-identical to the unmasked kernel); for int4 it doubles as the
    carrier of the logical width N the packed rows unpack to.

    Returns round_op(values[, scales], psi, mask, smoothed_sel, count_sel,
    data_sizes) -> (g_flat, dots, sqs, sqg, delta_flat, theta, theta_sm,
    w), where smoothed_sel/count_sel are the selected clients' angle-state
    slots and theta_sm applies Eq. 9 with the same float ops as core.fl's
    scatter-then-gather, so trajectories match the unsharded engines.
    """
    caxis = _client_axis(mesh)
    row_spec = P(caxis)
    if transport == "int4":
        from repro import transport as transport_mod

        group_size = group_size or transport_mod.GROUP_SIZE
        transport_mod.validate_group_size(group_size)

    def _body(values, scales, psi, mask, smoothed_sel, count_sel,
              data_sizes):
        my = _shard_slots(values, caxis)
        n = mask.shape[0]  # logical width (!= packed width for int4)
        kw = dict(transport=transport, group_size=group_size)
        g_flat = jax.lax.psum(
            _shard_agg(psi[my], values, scales, interpret, n=n, **kw),
            caxis)
        d_loc, s_loc, sqg = _shard_stats(values, scales, g_flat, mask,
                                         interpret, **kw)
        k = psi.shape[0]
        dots = jax.lax.psum(
            jnp.zeros((k,), jnp.float32).at[my].set(d_loc), caxis)
        sqs = jax.lax.psum(
            jnp.zeros((k,), jnp.float32).at[my].set(s_loc), caxis)
        theta = weighting.instantaneous_angle(dots, sqs, sqg)
        cnt = count_sel.astype(jnp.float32) + 1.0
        theta_sm = ((cnt - 1.0) * smoothed_sel + theta) / cnt  # Eq. 9
        if method == "fedadp":
            w = weighting.fedadp_weights(theta_sm, data_sizes, alpha)
            delta_flat = jax.lax.psum(
                _shard_agg(w[my], values, scales, interpret, n=n, **kw),
                caxis)
        else:  # w == psi: the stats' aggregate IS the round delta
            w = psi
            delta_flat = g_flat
        return g_flat, dots, sqs, sqg, delta_flat, theta, theta_sm, w

    outs = (P(),) * 8
    if transport in ("int8", "int4"):
        return _shard_map(_body, mesh,
                          in_specs=(row_spec, row_spec) + (P(),) * 5,
                          out_specs=outs)
    return _shard_map(
        lambda values, *rest: _body(values, None, *rest), mesh,
        in_specs=(row_spec,) + (P(),) * 5, out_specs=outs)


def make_buffered_flush_ops(mesh: Mesh, *, alpha: float,
                            method: str = "fedadp", beta: float = 0.0,
                            interpret: bool = True):
    """The buffered-async flush as ONE shard_map call (core/fl.py's
    aggregation="buffered" under engine="flat_sharded").

    Exactly `make_round_ops`' schedule — (1) psi-weighted psum, (2) stat
    psums, (3) replicated weighting, (4) weighted psum — but over the
    report buffer's rows instead of this round's uplink. Two differences:

    * No scales operands: wire compression happened at ADMISSION, so the
      buffer always holds dequantized f32 rows and the region streams
      them through the plain kernels regardless of the config transport.
    * Step (3) is the staleness-aware weighting
      (`weighting.buffered_*_weights`): sizes/age/landed ride in as
      replicated (K,) operands and non-landed rows — including client-
      axis padding rows, which must be padded landed=False — get exactly
      zero weight, so they contribute nothing to the aggregate psum.

    flush_op(values, psi, mask, smoothed_sel, count_sel, sizes, age,
    landed) -> (g_flat, dots, sqs, sqg, delta_flat, theta, theta_sm, w),
    mirroring `make_round_ops`' output row so core/fl.py's buffered path
    consumes both identically.
    """
    caxis = _client_axis(mesh)
    row_spec = P(caxis)

    def _body(values, psi, mask, smoothed_sel, count_sel, sizes, age,
              landed):
        my = _shard_slots(values, caxis)
        g_flat = jax.lax.psum(
            weighted_agg_mod.weighted_agg(
                psi[my], values, interpret=interpret,
                out_dtype=jnp.float32),
            caxis)
        d_loc, s_loc, sqg = round_stats_mod.round_stats(
            values, g_flat, mask, interpret=interpret)
        k = psi.shape[0]
        dots = jax.lax.psum(
            jnp.zeros((k,), jnp.float32).at[my].set(d_loc), caxis)
        sqs = jax.lax.psum(
            jnp.zeros((k,), jnp.float32).at[my].set(s_loc), caxis)
        theta = weighting.instantaneous_angle(dots, sqs, sqg)
        cnt = count_sel.astype(jnp.float32) + 1.0
        theta_sm = ((cnt - 1.0) * smoothed_sel + theta) / cnt  # Eq. 9
        if method == "fedadp":
            w = weighting.buffered_fedadp_weights(
                theta_sm, sizes, age, landed, alpha, beta)
        else:
            w = weighting.buffered_fedavg_weights(sizes, age, landed, beta)
        delta_flat = jax.lax.psum(
            weighted_agg_mod.weighted_agg(
                w[my], values, interpret=interpret, out_dtype=jnp.float32),
            caxis)
        return g_flat, dots, sqs, sqg, delta_flat, theta, theta_sm, w

    return _shard_map(_body, mesh, in_specs=(row_spec,) + (P(),) * 7,
                      out_specs=(P(),) * 8)


def fedadp_aggregate(mesh: Mesh, delta_pspecs: PyTree, *, alpha: float,
                     method: str = "fedadp", engine: str = "tree",
                     interpret: bool = True, transport: str = "f32",
                     group_size: int = 0):
    """Build an aggregation fn over K-stacked deltas.

    delta_pspecs: PartitionSpec tree for the STACKED deltas — leading axis
    = client axis over ("pod","data"), remaining dims per param sharding.

    engine="tree" (reference) runs per-leaf reductions and supports
    model-axis-sharded leaves; engine="flat" ravels the stacked tree into a
    client-row-sharded (K, N) buffer and runs the fused Pallas kernels per
    shard in ONE shard_map region (`make_round_ops`) — it requires
    client-only sharding and is the large-cohort fast path. `interpret` is
    the Pallas interpret switch for the flat engine (True off-TPU);
    `transport` (flat engine only) compresses the buffer to the wire dtype
    before aggregation (repro.transport; f32 is the reference wire).

    Returns agg(deltas, data_sizes, smoothed_prev, count_prev) ->
      (weighted_delta, theta, theta_smoothed, weights); weighted_delta is
      sharded like one param tree (tree engine) or replicated f32 (flat
      engine). smoothed/count are the selected clients' angle-state slots
      (Eq. 9 is applied inside, matching core.fl).
    """
    if engine == "flat":
        return _fedadp_aggregate_flat(mesh, delta_pspecs, alpha=alpha,
                                      method=method, interpret=interpret,
                                      transport=transport,
                                      group_size=group_size)
    if engine != "tree":
        raise ValueError(f"unknown engine {engine!r}")
    if transport != "f32":
        raise ValueError(
            "the tree engine never reads quantized buffers (ROADMAP "
            "transport contract); use engine='flat' for transport="
            f"{transport!r}")
    caxes = _client_axes(mesh)
    caxis = caxes if len(caxes) > 1 else caxes[0]

    spec_leaves = jax.tree.leaves(delta_pspecs, is_leaf=lambda x: isinstance(x, P))
    out_specs_leaves = [P(*s[1:]) for s in spec_leaves]  # drop client axis

    def body(deltas, data_sizes, smoothed_prev, count_prev):
        # deltas: local shard — leaves (K_loc, ...); replicated args full (K,)
        leaves = jax.tree.leaves(deltas)
        k_loc = leaves[0].shape[0]
        idx = jax.lax.axis_index(caxis)  # flattened over (pod, data)
        my_slots = idx * k_loc + jnp.arange(k_loc)

        psi_avg = weighting.fedavg_weights(data_sizes)

        def wsum(w_full):
            """psum over clients of w[k] * delta[k] (model shard local)."""
            w_loc = w_full[my_slots]

            def leaf(x):
                xf = x.astype(jnp.float32)
                part = jnp.tensordot(w_loc, xf, axes=1)
                return jax.lax.psum(part, caxis)

            return jax.tree.map(leaf, deltas)

        g_avg = wsum(psi_avg)  # (1)

        # (2) per-local-client stats, then psum over the non-client axes.
        # A leaf NOT sharded over some tensor axis is replicated there and
        # would be counted size(axis) times by that psum — divide each
        # leaf's contribution by its replication factor first.
        other_axes = tuple(a for a in mesh.axis_names if a not in caxes)

        def repl_factor(spec):
            used = set()
            for entry in tuple(spec)[1:]:
                if entry is None:
                    continue
                for a in (entry if isinstance(entry, tuple) else (entry,)):
                    used.add(a)
            f = 1
            for a in other_axes:
                if a not in used:
                    f *= mesh.shape[a]
            return float(f)

        def stats(x, g, spec):
            xf = x.astype(jnp.float32)
            gf = g.astype(jnp.float32)[None]
            axes_ = tuple(range(1, xf.ndim))
            inv = 1.0 / repl_factor(spec)
            return (jnp.sum(xf * gf, axis=axes_) * inv,
                    jnp.sum(xf * xf, axis=axes_) * inv,
                    jnp.sum(gf[0] * gf[0]) * inv)

        parts = [stats(x, g, s) for x, g, s in
                 zip(leaves, jax.tree.leaves(g_avg), spec_leaves)]
        dot_loc = sum(p[0] for p in parts)
        sq_loc = sum(p[1] for p in parts)
        sqg = sum(p[2] for p in parts)
        if other_axes:
            dot_loc = jax.lax.psum(dot_loc, other_axes)
            sq_loc = jax.lax.psum(sq_loc, other_axes)
            sqg = jax.lax.psum(sqg, other_axes)

        # gather per-client stats to all shards (K,) — O(K) scalars
        k_total = data_sizes.shape[0]
        dot_full = jnp.zeros((k_total,), jnp.float32).at[my_slots].set(dot_loc)
        sq_full = jnp.zeros((k_total,), jnp.float32).at[my_slots].set(sq_loc)
        dot_full = jax.lax.psum(dot_full, caxis)
        sq_full = jax.lax.psum(sq_full, caxis)

        theta = weighting.instantaneous_angle(dot_full, sq_full, sqg)  # (3)
        cnt = count_prev.astype(jnp.float32) + 1.0
        theta_sm = ((cnt - 1.0) * smoothed_prev + theta) / cnt  # Eq. 9
        if method == "fedadp":
            w = weighting.fedadp_weights(theta_sm, data_sizes, alpha)
        else:
            w = psi_avg
        return wsum(w), theta, theta_sm, w  # (4)

    tree_of = lambda leaves: jax.tree.unflatten(
        jax.tree.structure(delta_pspecs, is_leaf=lambda x: isinstance(x, P)),
        leaves,
    )
    in_specs = (tree_of(spec_leaves), P(), P(), P())
    out_specs = (tree_of(out_specs_leaves), P(), P(), P())
    return _shard_map(body, mesh, in_specs, out_specs)


def _fedadp_aggregate_flat(mesh: Mesh, delta_pspecs: PyTree, *, alpha: float,
                           method: str, interpret: bool,
                           transport: str = "f32", group_size: int = 0):
    """The flat engine behind `fedadp_aggregate(engine="flat")`.

    Same collective schedule as the tree engine — (1) psi-weighted psum,
    (2) per-client stat psums, (3) replicated weighting, (4) weighted psum
    — but each shard's contribution streams through the fused kernels over
    its contiguous (K_loc, N) rows, and the whole round is ONE shard_map
    region (`make_round_ops`). transport != "f32" compresses the raveled
    buffer to the wire dtype first; the kernels dequantize in-register.
    """
    from repro import transport as transport_mod

    if transport == "int4" and not group_size:
        group_size = transport_mod.GROUP_SIZE
    spec_leaves = jax.tree.leaves(delta_pspecs,
                                  is_leaf=lambda x: isinstance(x, P))
    for s in spec_leaves:
        if any(e is not None for e in tuple(s)[1:]):
            raise ValueError(
                "engine='flat' ravels each client's delta into one "
                f"contiguous row and requires client-only sharding; got {s} "
                "(use engine='tree' for model-axis-sharded leaves)")
    round_op = make_round_ops(mesh, alpha=alpha, method=method,
                              interpret=interpret, transport=transport,
                              group_size=group_size)
    row_sharding = flat_client_sharding(mesh)

    def body(deltas, data_sizes, smoothed_prev, count_prev):
        k = data_sizes.shape[0]
        csize = client_axis_size(mesh)
        if k % csize:
            raise ValueError(
                f"engine='flat' needs K divisible by the client-axis size "
                f"(K={k}, client axis {csize}); pad the cohort or use "
                "engine='tree'")
        flat, unravel = treemath.tree_ravel_stacked(deltas, row_sharding)
        psi_avg = weighting.fedavg_weights(data_sizes)
        ones = jnp.ones((flat.shape[1],), jnp.float32)
        if transport == "f32":
            wire = (flat,)
        else:
            q = transport_mod.quantize(
                flat, transport,
                group_size=group_size or transport_mod.GROUP_SIZE)
            values = jax.lax.with_sharding_constraint(q.values, row_sharding)
            wire = (values,) if q.scales is None else (
                values,
                jax.lax.with_sharding_constraint(q.scales, row_sharding))
        _, _, _, _, delta_flat, theta, theta_sm, w = round_op(
            *wire, psi_avg, ones, smoothed_prev, count_prev, data_sizes)
        return unravel(delta_flat, jnp.float32), theta, theta_sm, w

    return body
