"""FedAdp aggregation as an explicit shard_map collective schedule.

The pjit engine (core/fl.py) leaves collective placement to GSPMD. This
module expresses the SAME aggregation — the paper's actual contribution —
with hand-placed collectives under `jax.shard_map`, which makes the
communication pattern auditable and lets §Perf reason about it directly:

  per model-shard:   g_avg = psum_{clients}(psi_i * delta_i)        (1)
  per client:        dot_i = psum_{model}(<delta_i, g_avg>_shard)   (2)
                     |d_i|^2, |g|^2 likewise
  replicated:        theta -> Gompertz -> softmax weights           (3)
  per model-shard:   delta = psum_{clients}(w_i * delta_i)          (4)

Exactly two client-axis tree reductions (1)(4) plus O(K) scalar psums (2)
per round — the minimum the algorithm admits with exact same-round angles.

Works on any mesh whose client axis is "data" (+"pod") and whose tensor
axes follow models/sharding.param_pspecs; on a 1x1 host mesh it reduces to
plain math (used by the CPU equivalence test).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import weighting

PyTree = Any


def _client_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fedadp_aggregate(mesh: Mesh, delta_pspecs: PyTree, *, alpha: float,
                     method: str = "fedadp"):
    """Build an aggregation fn over K-stacked deltas.

    delta_pspecs: PartitionSpec tree for the STACKED deltas — leading axis
    = client axis over ("pod","data"), remaining dims per param sharding.

    Returns agg(deltas, data_sizes, smoothed_prev, count_prev) ->
      (weighted_delta, theta, theta_smoothed, weights); weighted_delta is
      sharded like one param tree. smoothed/count are the selected clients'
      angle-state slots (Eq. 9 is applied inside, matching core.fl).
    """
    caxes = _client_axes(mesh)
    caxis = caxes if len(caxes) > 1 else caxes[0]

    spec_leaves = jax.tree.leaves(delta_pspecs, is_leaf=lambda x: isinstance(x, P))
    out_specs_leaves = [P(*s[1:]) for s in spec_leaves]  # drop client axis

    def body(deltas, data_sizes, smoothed_prev, count_prev):
        # deltas: local shard — leaves (K_loc, ...); replicated args full (K,)
        leaves = jax.tree.leaves(deltas)
        k_loc = leaves[0].shape[0]
        idx = jax.lax.axis_index(caxis)  # flattened over (pod, data)
        my_slots = idx * k_loc + jnp.arange(k_loc)

        psi_avg = weighting.fedavg_weights(data_sizes)

        def wsum(w_full):
            """psum over clients of w[k] * delta[k] (model shard local)."""
            w_loc = w_full[my_slots]

            def leaf(x):
                xf = x.astype(jnp.float32)
                part = jnp.tensordot(w_loc, xf, axes=1)
                return jax.lax.psum(part, caxis)

            return jax.tree.map(leaf, deltas)

        g_avg = wsum(psi_avg)  # (1)

        # (2) per-local-client stats, then psum over the non-client axes.
        # A leaf NOT sharded over some tensor axis is replicated there and
        # would be counted size(axis) times by that psum — divide each
        # leaf's contribution by its replication factor first.
        other_axes = tuple(a for a in mesh.axis_names if a not in caxes)

        def repl_factor(spec):
            used = set()
            for entry in tuple(spec)[1:]:
                if entry is None:
                    continue
                for a in (entry if isinstance(entry, tuple) else (entry,)):
                    used.add(a)
            f = 1
            for a in other_axes:
                if a not in used:
                    f *= mesh.shape[a]
            return float(f)

        def stats(x, g, spec):
            xf = x.astype(jnp.float32)
            gf = g.astype(jnp.float32)[None]
            axes_ = tuple(range(1, xf.ndim))
            inv = 1.0 / repl_factor(spec)
            return (jnp.sum(xf * gf, axis=axes_) * inv,
                    jnp.sum(xf * xf, axis=axes_) * inv,
                    jnp.sum(gf[0] * gf[0]) * inv)

        parts = [stats(x, g, s) for x, g, s in
                 zip(leaves, jax.tree.leaves(g_avg), spec_leaves)]
        dot_loc = sum(p[0] for p in parts)
        sq_loc = sum(p[1] for p in parts)
        sqg = sum(p[2] for p in parts)
        if other_axes:
            dot_loc = jax.lax.psum(dot_loc, other_axes)
            sq_loc = jax.lax.psum(sq_loc, other_axes)
            sqg = jax.lax.psum(sqg, other_axes)

        # gather per-client stats to all shards (K,) — O(K) scalars
        k_total = data_sizes.shape[0]
        dot_full = jnp.zeros((k_total,), jnp.float32).at[my_slots].set(dot_loc)
        sq_full = jnp.zeros((k_total,), jnp.float32).at[my_slots].set(sq_loc)
        dot_full = jax.lax.psum(dot_full, caxis)
        sq_full = jax.lax.psum(sq_full, caxis)

        theta = weighting.instantaneous_angle(dot_full, sq_full, sqg)  # (3)
        cnt = count_prev.astype(jnp.float32) + 1.0
        theta_sm = ((cnt - 1.0) * smoothed_prev + theta) / cnt  # Eq. 9
        if method == "fedadp":
            w = weighting.fedadp_weights(theta_sm, data_sizes, alpha)
        else:
            w = psi_avg
        return wsum(w), theta, theta_sm, w  # (4)

    tree_of = lambda leaves: jax.tree.unflatten(
        jax.tree.structure(delta_pspecs, is_leaf=lambda x: isinstance(x, P)),
        leaves,
    )
    in_specs = (tree_of(spec_leaves), P(), P(), P())
    out_specs = (tree_of(out_specs_leaves), P(), P(), P())
    try:
        smap = jax.shard_map
    except AttributeError:  # older jax
        from jax.experimental.shard_map import shard_map as smap
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    try:
        return smap(body, check_vma=False, **kw)
    except TypeError:  # jax < 0.6 spells it check_rep
        return smap(body, check_rep=False, **kw)
