"""FedAdp aggregation as an explicit shard_map collective schedule.

The pjit engine (core/fl.py) leaves collective placement to GSPMD. This
module expresses the SAME aggregation — the paper's actual contribution —
with hand-placed collectives under `jax.shard_map`, which makes the
communication pattern auditable and lets §Perf reason about it directly:

  per model-shard:   g_avg = psum_{clients}(psi_i * delta_i)        (1)
  per client:        dot_i = psum_{model}(<delta_i, g_avg>_shard)   (2)
                     |d_i|^2, |g|^2 likewise
  replicated:        theta -> Gompertz -> softmax weights           (3)
  per model-shard:   delta = psum_{clients}(w_i * delta_i)          (4)

Exactly two client-axis tree reductions (1)(4) plus O(K) scalar psums (2)
per round — the minimum the algorithm admits with exact same-round angles.

Two engines share this schedule:

* ``engine="tree"`` (reference) — per-leaf reductions; tensor dims may be
  sharded over the model axes, so big-model leaves stay sharded.
* ``engine="flat"`` — the stacked deltas are raveled once into a (K, N)
  f32 buffer row-sharded over the client axis ("pod","data"); steps
  (1)(2)(4) run as the fused Pallas kernels (`kernels.weighted_agg`,
  `kernels.round_stats`) on each shard's rows, followed by the same psums.
  This is the scalable large-cohort path: per-device work is one HBM pass
  over K/num_shards rows regardless of K. On a client-only mesh each
  client's delta row is contiguous on its shard; on a 2D (client x
  model) mesh the buffer becomes a grid of (K_loc, N_loc) tiles instead
  (`make_round_ops_2d`) — each model shard ravels its LOCAL leaf blocks
  (treemath.blocked_ravel_local, no all-gather), quantization chunks are
  shard-local (the 2D wire layout), dots/sqnorms psum over both axes and
  the aggregates over the client axis only, so model-sharded leaves stay
  sharded end-to-end, for flat exactly as for tree.

`make_round_ops` packages the whole flat round — stats psums, the
replicated O(K) weighting, and the aggregate psum — as ONE shard_map
region; core/fl.py's `engine="flat_sharded"` round path reuses it so the
pjit and shard_map stacks aggregate through literally the same kernels.
The RoundState contract lives one level up: core/fl.py gathers the
selected clients' Eq. 9 slots out of `RoundState.angle` before entering
this region and scatters the results back after it, so the shard_map
schedule stays a pure (K,)-shaped aggregation op and the region composes
unchanged with the scanned driver (`core.driver` puts the whole round —
this region included — inside `lax.scan`).

Works on any mesh whose client axis is "data" (+"pod") and whose tensor
axes follow models/sharding.param_pspecs; on a 1x1 host mesh it reduces to
plain math (used by the CPU equivalence test).

Telemetry contract: the per-client quantities this schedule produces
(theta, smoothed theta, softmax weights) leave the shard_map region
replicated, so the `FLConfig(telemetry="node")` tel/* metrics built from
them in core/fl.py are exact per-node rows — identical across shards and
matching the unsharded engines to 1e-5 (pinned by tests/test_telemetry.py's
8-device subprocess leg).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import treemath, weighting
from repro.kernels import round_stats as round_stats_mod
from repro.kernels import weighted_agg as weighted_agg_mod

PyTree = Any


MODEL_AXIS = "model"


def _client_axes(mesh: Mesh):
    caxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not caxes:
        raise ValueError(
            f"mesh axes {mesh.axis_names} contain no client axis — the "
            "FedAdp client dimension shards over ('pod', 'data')")
    return caxes


def model_axis_size(mesh: Mesh) -> int:
    """Size of the mesh's "model" axis (1 when absent): > 1 selects the
    2D (client x model) layout for the flat engine."""
    if mesh is None or MODEL_AXIS not in mesh.axis_names:
        return 1
    return int(mesh.shape[MODEL_AXIS])


def client_axis_size(mesh: Mesh) -> int:
    size = 1
    for a in _client_axes(mesh):
        size *= mesh.shape[a]
    return size


def flat_client_sharding(mesh: Mesh) -> NamedSharding:
    """Row sharding for the (K, N) flat delta buffer: K over ("pod","data")."""
    caxes = _client_axes(mesh)
    return NamedSharding(mesh, P(caxes if len(caxes) > 1 else caxes[0]))


def _shard_map(body, mesh: Mesh, in_specs, out_specs):
    """shard_map across jax versions (check_vma / check_rep spelling)."""
    try:
        smap = jax.shard_map
    except AttributeError:  # older jax
        from jax.experimental.shard_map import shard_map as smap
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    try:
        return smap(body, check_vma=False, **kw)
    except TypeError:  # jax < 0.6 spells it check_rep
        return smap(body, check_rep=False, **kw)


def _client_axis(mesh: Mesh):
    caxes = _client_axes(mesh)
    return caxes if len(caxes) > 1 else caxes[0]


def _shard_slots(values, caxis):
    """Global client slots owned by this shard (rows are client-sharded)."""
    k_loc = values.shape[0]
    return jax.lax.axis_index(caxis) * k_loc + jnp.arange(k_loc)


def _shard_agg(w_loc, values, scales, interpret, *, transport, n,
               group_size):
    """Per-shard weighted aggregation over the local rows, f32 out.

    scales is None for f32/bf16 wire buffers (the kernels' in-VMEM
    astype(f32) IS the bf16 dequant); int8 routes through the fused
    in-register dequant kernel with the per-(client, chunk) scales, int4
    through the grouped-scale packed-nibble kernel (`n` is the logical
    width the packed buffer unpacks to).
    """
    if scales is None:
        return weighted_agg_mod.weighted_agg(
            w_loc, values, interpret=interpret, out_dtype=jnp.float32)
    if transport == "int4":
        return weighted_agg_mod.weighted_agg_q4(
            w_loc, values, scales, n=n, group_size=group_size,
            interpret=interpret)
    return weighted_agg_mod.weighted_agg_q(
        w_loc, values, scales, interpret=interpret)


def _shard_stats(values, scales, g_flat, mask, interpret, *, transport,
                 group_size):
    """Per-shard fused angle statistics over the local rows."""
    if scales is None:
        return round_stats_mod.round_stats(
            values, g_flat, mask, interpret=interpret)
    if transport == "int4":
        return round_stats_mod.round_stats_q4(
            values, scales, g_flat, mask, group_size=group_size,
            interpret=interpret)
    return round_stats_mod.round_stats_q(
        values, scales, g_flat, mask, interpret=interpret)


def make_round_ops(mesh: Mesh, *, alpha: float, method: str = "fedadp",
                   interpret: bool = True, transport: str = "f32",
                   group_size: int = 0):
    """The whole aggregation round as ONE shard_map call.

    PR 2's `make_flat_ops` exposed stats and aggregate as two separate
    shard_map regions, which re-entered the collective schedule (and
    re-staged the row shards) between them. The weighting in between is
    O(K) replicated scalar math — Eq. 9 smoothing + Gompertz softmax — so
    it folds into the same region: stats psums -> replicated weighting ->
    aggregate psum, one schedule, the buffer staying put on its shard
    (the two-region form is gone; this is the only flat schedule). For
    fedavg/fedprox the weighting IS psi, so the aggregate reuses the
    stats' g_flat and the round is a single client-axis reduction.

    transport selects the buffer's wire dtype (repro.transport):
    "f32"/"bf16" stream it through the plain kernels (bf16 dequant is the
    kernels' in-VMEM astype); "int8" adds a row-sharded
    (K, num_chunks(N)) f32 scales operand and routes through the fused
    in-register dequant kernels; "int4" row-shards the PACKED
    (K, ceil(N/2)) byte buffer plus its (K, num_groups) grouped scales
    (`group_size` required) through the packed-nibble kernels — in every
    case the per-shard partial dots/sqnorms and aggregates are psum'd
    exactly as in the f32 path, so scales never cross shards. mask is a
    REQUIRED (N,) f32 vector in LOGICAL element space (pass ones for
    unfiltered stats — multiplying by 1.0 is exact in f32, so the result
    is bit-identical to the unmasked kernel); for int4 it doubles as the
    carrier of the logical width N the packed rows unpack to.

    Returns round_op(values[, scales], psi, mask, smoothed_sel, count_sel,
    data_sizes) -> (g_flat, dots, sqs, sqg, delta_flat, theta, theta_sm,
    w), where smoothed_sel/count_sel are the selected clients' angle-state
    slots and theta_sm applies Eq. 9 with the same float ops as core.fl's
    scatter-then-gather, so trajectories match the unsharded engines.
    """
    caxis = _client_axis(mesh)
    row_spec = P(caxis)
    if transport == "int4":
        from repro import transport as transport_mod

        group_size = group_size or transport_mod.GROUP_SIZE
        transport_mod.validate_group_size(group_size)

    def _body(values, scales, psi, mask, smoothed_sel, count_sel,
              data_sizes):
        my = _shard_slots(values, caxis)
        n = mask.shape[0]  # logical width (!= packed width for int4)
        kw = dict(transport=transport, group_size=group_size)
        g_flat = jax.lax.psum(
            _shard_agg(psi[my], values, scales, interpret, n=n, **kw),
            caxis)
        d_loc, s_loc, sqg = _shard_stats(values, scales, g_flat, mask,
                                         interpret, **kw)
        k = psi.shape[0]
        dots = jax.lax.psum(
            jnp.zeros((k,), jnp.float32).at[my].set(d_loc), caxis)
        sqs = jax.lax.psum(
            jnp.zeros((k,), jnp.float32).at[my].set(s_loc), caxis)
        theta = weighting.instantaneous_angle(dots, sqs, sqg)
        cnt = count_sel.astype(jnp.float32) + 1.0
        theta_sm = ((cnt - 1.0) * smoothed_sel + theta) / cnt  # Eq. 9
        if method == "fedadp":
            w = weighting.fedadp_weights(theta_sm, data_sizes, alpha)
            delta_flat = jax.lax.psum(
                _shard_agg(w[my], values, scales, interpret, n=n, **kw),
                caxis)
        else:  # w == psi: the stats' aggregate IS the round delta
            w = psi
            delta_flat = g_flat
        return g_flat, dots, sqs, sqg, delta_flat, theta, theta_sm, w

    outs = (P(),) * 8
    if transport in ("int8", "int4"):
        return _shard_map(_body, mesh,
                          in_specs=(row_spec, row_spec) + (P(),) * 5,
                          out_specs=outs)
    return _shard_map(
        lambda values, *rest: _body(values, None, *rest), mesh,
        in_specs=(row_spec,) + (P(),) * 5, out_specs=outs)


def _spec_tree(pspecs):
    """(leaves, unflatten) over a PartitionSpec tree."""
    is_p = lambda x: isinstance(x, P)
    leaves = jax.tree.leaves(pspecs, is_leaf=is_p)
    structure = jax.tree.structure(pspecs, is_leaf=is_p)
    return leaves, lambda ls: jax.tree.unflatten(structure, ls)


def _stacked_specs(pspecs, caxis):
    """Stacked-delta specs: client axis leading, param dims per pspec."""
    leaves, tree_of = _spec_tree(pspecs)
    return tree_of([P(caxis, *tuple(s)) for s in leaves])


def _blocked_unstack_local(vec, layout, *, dtypes=None, gather_rows=False):
    """Per-leaf outputs from a blocked (…, width) array, inside the region.

    A model-sharded leaf's segment IS its local block (reshape only — the
    leaf stays sharded); a replicated leaf's column slices are re-joined
    with a small all_gather over the model axis (O(leaf size), never the
    full buffer). `gather_rows=True` handles (k_loc, width) row blocks
    (gather on axis 1), else (width,) vectors.
    """
    import math as _math

    m = layout.n_shards
    segs = treemath.blocked_split(vec, layout)
    out = []
    for i, (seg, shape, sdim) in enumerate(
            zip(segs, layout.shapes, layout.sharded_dims)):
        dt = layout.dtypes[i] if dtypes is None else dtypes
        lead = vec.shape[:-1]
        if sdim >= 0:
            local = list(shape)
            local[sdim] //= m
            out.append(seg.reshape(lead + tuple(local)).astype(dt))
        else:
            axis = 1 if gather_rows else 0
            full = jax.lax.all_gather(seg, MODEL_AXIS, axis=axis,
                                      tiled=True)
            size = _math.prod(shape) if shape else 1
            full = jax.lax.slice_in_dim(full, 0, size, axis=axis)
            out.append(full.reshape(lead + shape).astype(dt))
    return out


def make_round_ops_2d(mesh: Mesh, template_stacked: PyTree, pspecs: PyTree,
                      *, alpha: float, method: str = "fedadp",
                      interpret: bool = True, transport: str = "f32",
                      group_size: int = 0, keep=None):
    """`make_round_ops` on a 2D (client x model) mesh — tree in, tree out.

    The flat buffer becomes a P(caxis, "model") grid of (K_loc, N_loc)
    tiles: each device RAVELS its local stacked leaf blocks in-region
    (treemath.blocked_ravel_local — model-sharded leaves reshape locally,
    replicated leaves ceil-split column-wise, so no leaf is ever gathered
    to full width), quantizes them shard-locally (transport != "f32":
    int8/int4 scale chunks are per-shard, never straddling a model-axis
    split — THE wire layout on 2D meshes), and runs the fused kernels on
    its tile. Partial dots/sqnorms psum over BOTH axes; sqg over the
    model axis only (g is already client-reduced); the replicated Eq. 9 +
    Gompertz softmax stays scalar; and the two aggregates psum over the
    client axis ONLY — aggregated columns stay model-sharded, so the tree
    contract's "keeps sharded leaves sharded" now holds for flat too
    (replicated leaves re-join via an O(leaf) all_gather of their column
    slices).

    `template_stacked`/`pspecs`: the K-stacked delta tree (leading axis
    padded to the client-axis size) and the UNSTACKED param
    PartitionSpecs (models/sharding.param_pspecs — buffer sharding is
    config-derived). `keep`: per-leaf bool angle-filter flags (None =
    all; replaces the 1D form's mask operand, baked as a shard-identical
    (N_loc,) constant).

    Returns round_op(deltas_stacked, psi, smoothed_sel, count_sel,
    data_sizes) -> (g_tree, dots, sqs, sqg, delta_tree, theta, theta_sm,
    w): the 1D op's 8-tuple with the two flat vectors replaced by
    UNSTACKED f32 trees, sharded per `pspecs`.
    """
    caxes = _client_axes(mesh)
    caxis = caxes if len(caxes) > 1 else caxes[0]
    msize = model_axis_size(mesh)
    if msize <= 1:
        raise ValueError(
            "make_round_ops_2d needs a mesh with a 'model' axis of size "
            "> 1; use make_round_ops for client-only sharding")
    layout = treemath.blocked_layout(template_stacked, pspecs, msize,
                                     MODEL_AXIS)
    if transport == "int4":
        from repro import transport as transport_mod

        group_size = group_size or transport_mod.GROUP_SIZE
        transport_mod.validate_group_size(group_size)
    mask_const = treemath.blocked_segment_mask(layout, keep)
    n_loc = layout.width
    kw = dict(transport=transport, group_size=group_size)

    def _body(deltas, psi, smoothed_sel, count_sel, data_sizes):
        j = jax.lax.axis_index(MODEL_AXIS)
        x = treemath.blocked_ravel_local(jax.tree.leaves(deltas), layout, j)
        if transport == "f32":
            values, scales = x, None
        else:
            from repro import transport as transport_mod

            q = transport_mod.quantize(
                x, transport,
                group_size=group_size or transport_mod.GROUP_SIZE)
            values, scales = q.values, q.scales
        my = _shard_slots(x, caxis)
        g_loc = jax.lax.psum(
            _shard_agg(psi[my], values, scales, interpret, n=n_loc, **kw),
            caxis)
        d_loc, s_loc, sqg_loc = _shard_stats(values, scales, g_loc,
                                             mask_const, interpret, **kw)
        kp = psi.shape[0]
        both = caxes + (MODEL_AXIS,)
        dots = jax.lax.psum(
            jnp.zeros((kp,), jnp.float32).at[my].set(d_loc), both)
        sqs = jax.lax.psum(
            jnp.zeros((kp,), jnp.float32).at[my].set(s_loc), both)
        sqg = jax.lax.psum(sqg_loc, MODEL_AXIS)
        theta = weighting.instantaneous_angle(dots, sqs, sqg)
        cnt = count_sel.astype(jnp.float32) + 1.0
        theta_sm = ((cnt - 1.0) * smoothed_sel + theta) / cnt  # Eq. 9
        if method == "fedadp":
            w = weighting.fedadp_weights(theta_sm, data_sizes, alpha)
            delta_loc = jax.lax.psum(
                _shard_agg(w[my], values, scales, interpret, n=n_loc, **kw),
                caxis)
        else:  # w == psi: the stats' aggregate IS the round delta
            w = psi
            delta_loc = g_loc
        g_tree = jax.tree.unflatten(
            jax.tree.structure(deltas),
            _blocked_unstack_local(g_loc, layout, dtypes=jnp.float32))
        delta_tree = jax.tree.unflatten(
            jax.tree.structure(deltas),
            _blocked_unstack_local(delta_loc, layout, dtypes=jnp.float32))
        return g_tree, dots, sqs, sqg, delta_tree, theta, theta_sm, w

    spec_leaves, tree_of = _spec_tree(pspecs)
    unstacked = tree_of(spec_leaves)
    in_specs = (_stacked_specs(pspecs, caxis), P(), P(), P(), P())
    out_specs = (unstacked, P(), P(), P(), unstacked, P(), P(), P())
    return _shard_map(_body, mesh, in_specs, out_specs)


def make_blocked_roundtrip(mesh: Mesh, template_stacked: PyTree,
                           pspecs: PyTree, *, transport: str,
                           group_size: int = 0):
    """Shard-local wire roundtrip for the TREE engine on a 2D mesh.

    On a (client x model) mesh the uplink wire is quantized per
    (client, model-shard) block — scale chunks are shard-local (see
    `make_round_ops_2d`). The tree reference must consume the SAME
    reconstruction without ever raveling a model-sharded leaf to full
    width (the global `tree_ravel_stacked` + quantize path would
    all-gather it): this region ravels each shard's local blocks, runs
    quantize -> dequantize on the (K_loc, N_loc) tile, and returns the
    STACKED f32 reconstruction — zero collectives for model-sharded
    leaves, an O(leaf) all_gather to re-join each replicated leaf's
    column slices. The tree engine then runs its per-leaf reference
    reductions on the result, preserving the "tree never reads the wire
    buffer" contract (it reads the dequantized tree).

    Returns roundtrip(deltas_stacked) -> stacked f32 tree, sharded like
    the input (client axis leading, tensor dims per `pspecs`).
    """
    caxes = _client_axes(mesh)
    caxis = caxes if len(caxes) > 1 else caxes[0]
    msize = model_axis_size(mesh)
    layout = treemath.blocked_layout(template_stacked, pspecs, msize,
                                     MODEL_AXIS)
    from repro import transport as transport_mod

    if transport == "int4":
        group_size = group_size or transport_mod.GROUP_SIZE
        transport_mod.validate_group_size(group_size)

    def _body(deltas):
        j = jax.lax.axis_index(MODEL_AXIS)
        x = treemath.blocked_ravel_local(jax.tree.leaves(deltas), layout, j)
        q = transport_mod.quantize(
            x, transport, group_size=group_size or transport_mod.GROUP_SIZE)
        recon = transport_mod.dequantize(q)  # (k_loc, N_loc) f32
        return jax.tree.unflatten(
            jax.tree.structure(deltas),
            _blocked_unstack_local(recon, layout, dtypes=jnp.float32,
                                   gather_rows=True))

    stacked = _stacked_specs(pspecs, caxis)
    return _shard_map(_body, mesh, in_specs=(stacked,), out_specs=stacked)


def make_buffered_flush_ops(mesh: Mesh, *, alpha: float,
                            method: str = "fedadp", beta: float = 0.0,
                            interpret: bool = True):
    """The buffered-async flush as ONE shard_map call (core/fl.py's
    aggregation="buffered" under engine="flat_sharded").

    Exactly `make_round_ops`' schedule — (1) psi-weighted psum, (2) stat
    psums, (3) replicated weighting, (4) weighted psum — but over the
    report buffer's rows instead of this round's uplink. Two differences:

    * No scales operands: wire compression happened at ADMISSION, so the
      buffer always holds dequantized f32 rows and the region streams
      them through the plain kernels regardless of the config transport.
    * Step (3) is the staleness-aware weighting
      (`weighting.buffered_*_weights`): sizes/age/landed ride in as
      replicated (K,) operands and non-landed rows — including client-
      axis padding rows, which must be padded landed=False — get exactly
      zero weight, so they contribute nothing to the aggregate psum.

    flush_op(values, psi, mask, smoothed_sel, count_sel, sizes, age,
    landed) -> (g_flat, dots, sqs, sqg, delta_flat, theta, theta_sm, w),
    mirroring `make_round_ops`' output row so core/fl.py's buffered path
    consumes both identically.

    On a 2D (client x model) mesh the buffer's COLUMNS also shard: the
    report buffer stays a global f32 (K, Np) array (admission is
    unchanged — it dequantizes at landing), but Np must be padded to a
    multiple of the model-axis size (core/fl.py pads with zero columns
    and slices the outputs back) and values/mask ride in as
    P(caxis, "model") / P("model") tiles. Each device flushes its
    (K_loc, Np/msize) tile; dots/sqs psum over both axes, sqg over the
    model axis, and the two aggregates psum over the client axis only —
    g_flat/delta_flat come back as model-sharded (Np,) vectors.
    """
    caxis = _client_axis(mesh)
    caxes = _client_axes(mesh)
    msize = model_axis_size(mesh)
    stat_axes = caxes + (MODEL_AXIS,) if msize > 1 else caxes

    def _body(values, psi, mask, smoothed_sel, count_sel, sizes, age,
              landed):
        my = _shard_slots(values, caxis)
        g_flat = jax.lax.psum(
            weighted_agg_mod.weighted_agg(
                psi[my], values, interpret=interpret,
                out_dtype=jnp.float32),
            caxis)
        d_loc, s_loc, sqg = round_stats_mod.round_stats(
            values, g_flat, mask, interpret=interpret)
        k = psi.shape[0]
        dots = jax.lax.psum(
            jnp.zeros((k,), jnp.float32).at[my].set(d_loc), stat_axes)
        sqs = jax.lax.psum(
            jnp.zeros((k,), jnp.float32).at[my].set(s_loc), stat_axes)
        if msize > 1:
            sqg = jax.lax.psum(sqg, MODEL_AXIS)
        theta = weighting.instantaneous_angle(dots, sqs, sqg)
        cnt = count_sel.astype(jnp.float32) + 1.0
        theta_sm = ((cnt - 1.0) * smoothed_sel + theta) / cnt  # Eq. 9
        if method == "fedadp":
            w = weighting.buffered_fedadp_weights(
                theta_sm, sizes, age, landed, alpha, beta)
        else:
            w = weighting.buffered_fedavg_weights(sizes, age, landed, beta)
        delta_flat = jax.lax.psum(
            weighted_agg_mod.weighted_agg(
                w[my], values, interpret=interpret, out_dtype=jnp.float32),
            caxis)
        return g_flat, dots, sqs, sqg, delta_flat, theta, theta_sm, w

    if msize > 1:
        col = P(MODEL_AXIS)
        return _shard_map(
            _body, mesh,
            in_specs=(P(caxis, MODEL_AXIS), P(), col) + (P(),) * 5,
            out_specs=(col, P(), P(), P(), col, P(), P(), P()))
    return _shard_map(_body, mesh, in_specs=(P(caxis),) + (P(),) * 7,
                      out_specs=(P(),) * 8)


def fedadp_aggregate(mesh: Mesh, delta_pspecs: PyTree, *, alpha: float,
                     method: str = "fedadp", engine: str = "tree",
                     interpret: bool = True, transport: str = "f32",
                     group_size: int = 0):
    """Build an aggregation fn over K-stacked deltas.

    delta_pspecs: PartitionSpec tree for the STACKED deltas — leading axis
    = client axis over ("pod","data"), remaining dims per param sharding.

    engine="tree" (reference) runs per-leaf reductions and supports
    model-axis-sharded leaves; engine="flat" ravels the stacked tree into a
    client-row-sharded (K, N) buffer and runs the fused Pallas kernels per
    shard in ONE shard_map region (`make_round_ops`) — it requires
    client-only sharding and is the large-cohort fast path. `interpret` is
    the Pallas interpret switch for the flat engine (True off-TPU);
    `transport` (flat engine only) compresses the buffer to the wire dtype
    before aggregation (repro.transport; f32 is the reference wire).

    Returns agg(deltas, data_sizes, smoothed_prev, count_prev) ->
      (weighted_delta, theta, theta_smoothed, weights); weighted_delta is
      sharded like one param tree (tree engine) or replicated f32 (flat
      engine). smoothed/count are the selected clients' angle-state slots
      (Eq. 9 is applied inside, matching core.fl).
    """
    if engine == "flat":
        return _fedadp_aggregate_flat(mesh, delta_pspecs, alpha=alpha,
                                      method=method, interpret=interpret,
                                      transport=transport,
                                      group_size=group_size)
    if engine != "tree":
        raise ValueError(f"unknown engine {engine!r}")
    if transport != "f32":
        raise ValueError(
            "the tree engine never reads quantized buffers (ROADMAP "
            "transport contract); use engine='flat' for transport="
            f"{transport!r}")
    caxes = _client_axes(mesh)
    caxis = caxes if len(caxes) > 1 else caxes[0]

    spec_leaves = jax.tree.leaves(delta_pspecs, is_leaf=lambda x: isinstance(x, P))
    out_specs_leaves = [P(*s[1:]) for s in spec_leaves]  # drop client axis

    def body(deltas, data_sizes, smoothed_prev, count_prev):
        # deltas: local shard — leaves (K_loc, ...); replicated args full (K,)
        leaves = jax.tree.leaves(deltas)
        k_loc = leaves[0].shape[0]
        idx = jax.lax.axis_index(caxis)  # flattened over (pod, data)
        my_slots = idx * k_loc + jnp.arange(k_loc)

        psi_avg = weighting.fedavg_weights(data_sizes)

        def wsum(w_full):
            """psum over clients of w[k] * delta[k] (model shard local)."""
            w_loc = w_full[my_slots]

            def leaf(x):
                xf = x.astype(jnp.float32)
                part = jnp.tensordot(w_loc, xf, axes=1)
                return jax.lax.psum(part, caxis)

            return jax.tree.map(leaf, deltas)

        g_avg = wsum(psi_avg)  # (1)

        # (2) per-local-client stats, then psum over the non-client axes.
        # A leaf NOT sharded over some tensor axis is replicated there and
        # would be counted size(axis) times by that psum — divide each
        # leaf's contribution by its replication factor first.
        other_axes = tuple(a for a in mesh.axis_names if a not in caxes)

        def repl_factor(spec):
            used = set()
            for entry in tuple(spec)[1:]:
                if entry is None:
                    continue
                for a in (entry if isinstance(entry, tuple) else (entry,)):
                    used.add(a)
            f = 1
            for a in other_axes:
                if a not in used:
                    f *= mesh.shape[a]
            return float(f)

        def stats(x, g, spec):
            xf = x.astype(jnp.float32)
            gf = g.astype(jnp.float32)[None]
            axes_ = tuple(range(1, xf.ndim))
            inv = 1.0 / repl_factor(spec)
            return (jnp.sum(xf * gf, axis=axes_) * inv,
                    jnp.sum(xf * xf, axis=axes_) * inv,
                    jnp.sum(gf[0] * gf[0]) * inv)

        parts = [stats(x, g, s) for x, g, s in
                 zip(leaves, jax.tree.leaves(g_avg), spec_leaves)]
        dot_loc = sum(p[0] for p in parts)
        sq_loc = sum(p[1] for p in parts)
        sqg = sum(p[2] for p in parts)
        if other_axes:
            dot_loc = jax.lax.psum(dot_loc, other_axes)
            sq_loc = jax.lax.psum(sq_loc, other_axes)
            sqg = jax.lax.psum(sqg, other_axes)

        # gather per-client stats to all shards (K,) — O(K) scalars
        k_total = data_sizes.shape[0]
        dot_full = jnp.zeros((k_total,), jnp.float32).at[my_slots].set(dot_loc)
        sq_full = jnp.zeros((k_total,), jnp.float32).at[my_slots].set(sq_loc)
        dot_full = jax.lax.psum(dot_full, caxis)
        sq_full = jax.lax.psum(sq_full, caxis)

        theta = weighting.instantaneous_angle(dot_full, sq_full, sqg)  # (3)
        cnt = count_prev.astype(jnp.float32) + 1.0
        theta_sm = ((cnt - 1.0) * smoothed_prev + theta) / cnt  # Eq. 9
        if method == "fedadp":
            w = weighting.fedadp_weights(theta_sm, data_sizes, alpha)
        else:
            w = psi_avg
        return wsum(w), theta, theta_sm, w  # (4)

    tree_of = lambda leaves: jax.tree.unflatten(
        jax.tree.structure(delta_pspecs, is_leaf=lambda x: isinstance(x, P)),
        leaves,
    )
    in_specs = (tree_of(spec_leaves), P(), P(), P())
    out_specs = (tree_of(out_specs_leaves), P(), P(), P())
    return _shard_map(body, mesh, in_specs, out_specs)


def _fedadp_aggregate_flat(mesh: Mesh, delta_pspecs: PyTree, *, alpha: float,
                           method: str, interpret: bool,
                           transport: str = "f32", group_size: int = 0):
    """The flat engine behind `fedadp_aggregate(engine="flat")`.

    Same collective schedule as the tree engine — (1) psi-weighted psum,
    (2) per-client stat psums, (3) replicated weighting, (4) weighted psum
    — but each shard's contribution streams through the fused kernels over
    its contiguous (K_loc, N) rows, and the whole round is ONE shard_map
    region (`make_round_ops`). transport != "f32" compresses the raveled
    buffer to the wire dtype first; the kernels dequantize in-register.

    On a 2D (client x model) mesh — `mesh.axis_names` containing "model"
    with size > 1 — the flat buffer becomes a (client x model) grid of
    tiles instead (`make_round_ops_2d`): model-sharded leaves ravel
    shard-locally (no all-gather), quantization chunks are shard-local
    (the 2D wire layout), and the aggregated delta keeps its model
    sharding, so the old "client-only sharding" restriction is gone.
    """
    from repro import transport as transport_mod

    if transport == "int4" and not group_size:
        group_size = transport_mod.GROUP_SIZE
    spec_leaves = jax.tree.leaves(delta_pspecs,
                                  is_leaf=lambda x: isinstance(x, P))
    if model_axis_size(mesh) > 1:
        return _fedadp_aggregate_flat_2d(
            mesh, delta_pspecs, alpha=alpha, method=method,
            interpret=interpret, transport=transport, group_size=group_size)
    for s in spec_leaves:
        if any(e is not None for e in tuple(s)[1:]):
            raise ValueError(
                "engine='flat' ravels each client's delta into one "
                f"contiguous row and requires client-only sharding; got {s} "
                "(add a 'model' mesh axis for the 2D flat engine, or use "
                "engine='tree' for model-axis-sharded leaves)")
    round_op = make_round_ops(mesh, alpha=alpha, method=method,
                              interpret=interpret, transport=transport,
                              group_size=group_size)
    row_sharding = flat_client_sharding(mesh)

    def body(deltas, data_sizes, smoothed_prev, count_prev):
        k = data_sizes.shape[0]
        csize = client_axis_size(mesh)
        if k % csize:
            raise ValueError(
                f"engine='flat' needs K divisible by the client-axis size "
                f"(K={k}, client axis {csize}); pad the cohort or use "
                "engine='tree'")
        flat, unravel = treemath.tree_ravel_stacked(deltas, row_sharding)
        psi_avg = weighting.fedavg_weights(data_sizes)
        ones = jnp.ones((flat.shape[1],), jnp.float32)
        if transport == "f32":
            wire = (flat,)
        else:
            q = transport_mod.quantize(
                flat, transport,
                group_size=group_size or transport_mod.GROUP_SIZE)
            values = jax.lax.with_sharding_constraint(q.values, row_sharding)
            wire = (values,) if q.scales is None else (
                values,
                jax.lax.with_sharding_constraint(q.scales, row_sharding))
        _, _, _, _, delta_flat, theta, theta_sm, w = round_op(
            *wire, psi_avg, ones, smoothed_prev, count_prev, data_sizes)
        return unravel(delta_flat, jnp.float32), theta, theta_sm, w

    return body


def _fedadp_aggregate_flat_2d(mesh: Mesh, delta_pspecs: PyTree, *,
                              alpha: float, method: str, interpret: bool,
                              transport: str, group_size: int):
    """`fedadp_aggregate(engine="flat")` on a (client x model) mesh."""
    spec_leaves = jax.tree.leaves(delta_pspecs,
                                  is_leaf=lambda x: isinstance(x, P))
    tree_of = lambda ls: jax.tree.unflatten(
        jax.tree.structure(delta_pspecs,
                           is_leaf=lambda x: isinstance(x, P)), ls)
    pspecs = tree_of([P(*tuple(s)[1:]) for s in spec_leaves])

    def body(deltas, data_sizes, smoothed_prev, count_prev):
        k = data_sizes.shape[0]
        csize = client_axis_size(mesh)
        if k % csize:
            raise ValueError(
                f"engine='flat' needs K divisible by the client-axis size "
                f"(K={k}, client axis {csize}); pad the cohort or use "
                "engine='tree'")
        round_op = make_round_ops_2d(
            mesh, deltas, pspecs, alpha=alpha, method=method,
            interpret=interpret, transport=transport,
            group_size=group_size)
        psi_avg = weighting.fedavg_weights(data_sizes)
        _, _, _, _, delta_tree, theta, theta_sm, w = round_op(
            deltas, psi_avg, smoothed_prev, count_prev, data_sizes)
        return delta_tree, theta, theta_sm, w

    return body
