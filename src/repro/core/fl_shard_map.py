"""FedAdp aggregation as an explicit shard_map collective schedule.

The pjit engine (core/fl.py) leaves collective placement to GSPMD. This
module expresses the SAME aggregation — the paper's actual contribution —
with hand-placed collectives under `jax.shard_map`, which makes the
communication pattern auditable and lets §Perf reason about it directly:

  per model-shard:   g_avg = psum_{clients}(psi_i * delta_i)        (1)
  per client:        dot_i = psum_{model}(<delta_i, g_avg>_shard)   (2)
                     |d_i|^2, |g|^2 likewise
  replicated:        theta -> Gompertz -> softmax weights           (3)
  per model-shard:   delta = psum_{clients}(w_i * delta_i)          (4)

Exactly two client-axis tree reductions (1)(4) plus O(K) scalar psums (2)
per round — the minimum the algorithm admits with exact same-round angles.

Two engines share this schedule:

* ``engine="tree"`` (reference) — per-leaf reductions; tensor dims may be
  sharded over the model axes, so big-model leaves stay sharded.
* ``engine="flat"`` — the stacked deltas are raveled once into a (K, N)
  f32 buffer row-sharded over the client axis ("pod","data"); steps
  (1)(2)(4) run as the fused Pallas kernels (`kernels.weighted_agg`,
  `kernels.round_stats`) on each shard's rows, followed by the same psums.
  This is the scalable large-cohort path: per-device work is one HBM pass
  over K/num_shards rows regardless of K. It requires client-only
  sharding (each client's delta row is contiguous on its shard).

`make_flat_ops` exposes the flat per-shard kernel + psum building blocks;
core/fl.py's `engine="flat_sharded"` round path reuses them so the pjit
and shard_map stacks aggregate through literally the same kernels.

Works on any mesh whose client axis is "data" (+"pod") and whose tensor
axes follow models/sharding.param_pspecs; on a 1x1 host mesh it reduces to
plain math (used by the CPU equivalence test).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import treemath, weighting
from repro.kernels import round_stats as round_stats_mod
from repro.kernels import weighted_agg as weighted_agg_mod

PyTree = Any


def _client_axes(mesh: Mesh):
    caxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not caxes:
        raise ValueError(
            f"mesh axes {mesh.axis_names} contain no client axis — the "
            "FedAdp client dimension shards over ('pod', 'data')")
    return caxes


def client_axis_size(mesh: Mesh) -> int:
    size = 1
    for a in _client_axes(mesh):
        size *= mesh.shape[a]
    return size


def flat_client_sharding(mesh: Mesh) -> NamedSharding:
    """Row sharding for the (K, N) flat delta buffer: K over ("pod","data")."""
    caxes = _client_axes(mesh)
    return NamedSharding(mesh, P(caxes if len(caxes) > 1 else caxes[0]))


def _shard_map(body, mesh: Mesh, in_specs, out_specs):
    """shard_map across jax versions (check_vma / check_rep spelling)."""
    try:
        smap = jax.shard_map
    except AttributeError:  # older jax
        from jax.experimental.shard_map import shard_map as smap
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    try:
        return smap(body, check_vma=False, **kw)
    except TypeError:  # jax < 0.6 spells it check_rep
        return smap(body, check_rep=False, **kw)


def make_flat_ops(mesh: Mesh, *, interpret: bool = True):
    """Client-sharded kernel ops over a (K, N) flat delta buffer.

    Returns (stats, agg) — both shard_map'd over the mesh client axis, with
    the buffer row-sharded (`flat_client_sharding`) and everything else
    replicated. K must be divisible by the client-axis size.

      stats(flat, psi, mask) -> (g_flat, dots, sqs, sqg):
        one per-shard `weighted_agg` for the psi-weighted global delta
        (psum over clients), then one per-shard `round_stats` pass against
        the replicated g; partial dots/sqnorms are scattered into (K,)
        and psum'd. mask is a REQUIRED (N,) f32 vector — pass ones for
        unfiltered stats (multiplying by 1.0 is exact in f32, so the
        result is bit-identical to the unmasked kernel).

      agg(flat, w) -> (N,): psum over clients of per-shard `weighted_agg`.
    """
    caxes = _client_axes(mesh)
    caxis = caxes if len(caxes) > 1 else caxes[0]
    row_spec = P(caxis)

    def _slots(flat):
        k_loc = flat.shape[0]
        return jax.lax.axis_index(caxis) * k_loc + jnp.arange(k_loc)

    def _stats_body(flat, psi, mask):
        my = _slots(flat)
        g_part = weighted_agg_mod.weighted_agg(psi[my], flat,
                                               interpret=interpret)
        g_flat = jax.lax.psum(g_part, caxis)
        d_loc, s_loc, sqg = round_stats_mod.round_stats(
            flat, g_flat, mask, interpret=interpret)
        k = psi.shape[0]
        dots = jax.lax.psum(
            jnp.zeros((k,), jnp.float32).at[my].set(d_loc), caxis)
        sqs = jax.lax.psum(
            jnp.zeros((k,), jnp.float32).at[my].set(s_loc), caxis)
        # g_flat is replicated post-psum, so sqg agrees across shards.
        return g_flat, dots, sqs, sqg

    def _agg_body(flat, w):
        part = weighted_agg_mod.weighted_agg(w[_slots(flat)], flat,
                                             interpret=interpret)
        return jax.lax.psum(part, caxis)

    stats = _shard_map(_stats_body, mesh, in_specs=(row_spec, P(), P()),
                       out_specs=(P(), P(), P(), P()))
    agg = _shard_map(_agg_body, mesh, in_specs=(row_spec, P()),
                     out_specs=P())
    return stats, agg


def fedadp_aggregate(mesh: Mesh, delta_pspecs: PyTree, *, alpha: float,
                     method: str = "fedadp", engine: str = "tree",
                     interpret: bool = True):
    """Build an aggregation fn over K-stacked deltas.

    delta_pspecs: PartitionSpec tree for the STACKED deltas — leading axis
    = client axis over ("pod","data"), remaining dims per param sharding.

    engine="tree" (reference) runs per-leaf reductions and supports
    model-axis-sharded leaves; engine="flat" ravels the stacked tree into a
    client-row-sharded (K, N) buffer and runs the fused Pallas kernels per
    shard (`make_flat_ops`) — it requires client-only sharding and is the
    large-cohort fast path. `interpret` is the Pallas interpret switch for
    the flat engine (True off-TPU).

    Returns agg(deltas, data_sizes, smoothed_prev, count_prev) ->
      (weighted_delta, theta, theta_smoothed, weights); weighted_delta is
      sharded like one param tree (tree engine) or replicated f32 (flat
      engine). smoothed/count are the selected clients' angle-state slots
      (Eq. 9 is applied inside, matching core.fl).
    """
    if engine == "flat":
        return _fedadp_aggregate_flat(mesh, delta_pspecs, alpha=alpha,
                                      method=method, interpret=interpret)
    if engine != "tree":
        raise ValueError(f"unknown engine {engine!r}")
    caxes = _client_axes(mesh)
    caxis = caxes if len(caxes) > 1 else caxes[0]

    spec_leaves = jax.tree.leaves(delta_pspecs, is_leaf=lambda x: isinstance(x, P))
    out_specs_leaves = [P(*s[1:]) for s in spec_leaves]  # drop client axis

    def body(deltas, data_sizes, smoothed_prev, count_prev):
        # deltas: local shard — leaves (K_loc, ...); replicated args full (K,)
        leaves = jax.tree.leaves(deltas)
        k_loc = leaves[0].shape[0]
        idx = jax.lax.axis_index(caxis)  # flattened over (pod, data)
        my_slots = idx * k_loc + jnp.arange(k_loc)

        psi_avg = weighting.fedavg_weights(data_sizes)

        def wsum(w_full):
            """psum over clients of w[k] * delta[k] (model shard local)."""
            w_loc = w_full[my_slots]

            def leaf(x):
                xf = x.astype(jnp.float32)
                part = jnp.tensordot(w_loc, xf, axes=1)
                return jax.lax.psum(part, caxis)

            return jax.tree.map(leaf, deltas)

        g_avg = wsum(psi_avg)  # (1)

        # (2) per-local-client stats, then psum over the non-client axes.
        # A leaf NOT sharded over some tensor axis is replicated there and
        # would be counted size(axis) times by that psum — divide each
        # leaf's contribution by its replication factor first.
        other_axes = tuple(a for a in mesh.axis_names if a not in caxes)

        def repl_factor(spec):
            used = set()
            for entry in tuple(spec)[1:]:
                if entry is None:
                    continue
                for a in (entry if isinstance(entry, tuple) else (entry,)):
                    used.add(a)
            f = 1
            for a in other_axes:
                if a not in used:
                    f *= mesh.shape[a]
            return float(f)

        def stats(x, g, spec):
            xf = x.astype(jnp.float32)
            gf = g.astype(jnp.float32)[None]
            axes_ = tuple(range(1, xf.ndim))
            inv = 1.0 / repl_factor(spec)
            return (jnp.sum(xf * gf, axis=axes_) * inv,
                    jnp.sum(xf * xf, axis=axes_) * inv,
                    jnp.sum(gf[0] * gf[0]) * inv)

        parts = [stats(x, g, s) for x, g, s in
                 zip(leaves, jax.tree.leaves(g_avg), spec_leaves)]
        dot_loc = sum(p[0] for p in parts)
        sq_loc = sum(p[1] for p in parts)
        sqg = sum(p[2] for p in parts)
        if other_axes:
            dot_loc = jax.lax.psum(dot_loc, other_axes)
            sq_loc = jax.lax.psum(sq_loc, other_axes)
            sqg = jax.lax.psum(sqg, other_axes)

        # gather per-client stats to all shards (K,) — O(K) scalars
        k_total = data_sizes.shape[0]
        dot_full = jnp.zeros((k_total,), jnp.float32).at[my_slots].set(dot_loc)
        sq_full = jnp.zeros((k_total,), jnp.float32).at[my_slots].set(sq_loc)
        dot_full = jax.lax.psum(dot_full, caxis)
        sq_full = jax.lax.psum(sq_full, caxis)

        theta = weighting.instantaneous_angle(dot_full, sq_full, sqg)  # (3)
        cnt = count_prev.astype(jnp.float32) + 1.0
        theta_sm = ((cnt - 1.0) * smoothed_prev + theta) / cnt  # Eq. 9
        if method == "fedadp":
            w = weighting.fedadp_weights(theta_sm, data_sizes, alpha)
        else:
            w = psi_avg
        return wsum(w), theta, theta_sm, w  # (4)

    tree_of = lambda leaves: jax.tree.unflatten(
        jax.tree.structure(delta_pspecs, is_leaf=lambda x: isinstance(x, P)),
        leaves,
    )
    in_specs = (tree_of(spec_leaves), P(), P(), P())
    out_specs = (tree_of(out_specs_leaves), P(), P(), P())
    return _shard_map(body, mesh, in_specs, out_specs)


def _fedadp_aggregate_flat(mesh: Mesh, delta_pspecs: PyTree, *, alpha: float,
                           method: str, interpret: bool):
    """The flat engine behind `fedadp_aggregate(engine="flat")`.

    Same collective schedule as the tree engine — (1) psi-weighted psum,
    (2) per-client stat psums, (3) replicated weighting, (4) weighted psum
    — but each shard's contribution streams through the fused kernels over
    its contiguous (K_loc, N) rows.
    """
    spec_leaves = jax.tree.leaves(delta_pspecs,
                                  is_leaf=lambda x: isinstance(x, P))
    for s in spec_leaves:
        if any(e is not None for e in tuple(s)[1:]):
            raise ValueError(
                "engine='flat' ravels each client's delta into one "
                f"contiguous row and requires client-only sharding; got {s} "
                "(use engine='tree' for model-axis-sharded leaves)")
    stats, agg = make_flat_ops(mesh, interpret=interpret)
    row_sharding = flat_client_sharding(mesh)

    def body(deltas, data_sizes, smoothed_prev, count_prev):
        k = data_sizes.shape[0]
        csize = client_axis_size(mesh)
        if k % csize:
            raise ValueError(
                f"engine='flat' needs K divisible by the client-axis size "
                f"(K={k}, client axis {csize}); pad the cohort or use "
                "engine='tree'")
        flat, unravel = treemath.tree_ravel_stacked(deltas, row_sharding)
        psi_avg = weighting.fedavg_weights(data_sizes)
        ones = jnp.ones((flat.shape[1],), jnp.float32)
        _, dots, sqs, sqg = stats(flat, psi_avg, ones)
        theta = weighting.instantaneous_angle(dots, sqs, sqg)
        cnt = count_prev.astype(jnp.float32) + 1.0
        theta_sm = ((cnt - 1.0) * smoothed_prev + theta) / cnt  # Eq. 9
        if method == "fedadp":
            w = weighting.fedadp_weights(theta_sm, data_sizes, alpha)
        else:
            w = psi_avg
        return unravel(agg(flat, w), jnp.float32), theta, theta_sm, w

    return body
